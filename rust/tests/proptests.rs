//! Cross-module property tests (util::proptest harness): coordinator-
//! level invariants over routing (dependency groups), batching (episode
//! walk order) and state management that unit tests in each module
//! don't cover jointly. Artifact-free — everything here runs on
//! synthetic specs.

use hapq::hw::dataflow::{map_layer, LayerDims};
use hapq::hw::energy::{Compression, EnergyModel};
use hapq::hw::mac_sim::RqTable;
use hapq::hw::Accel;
use hapq::pruning::{prune, PruneAlg, PruneCtx};
use hapq::quant::quantize_weights;
use hapq::tensor::Tensor;
use hapq::util::proptest::forall;
use hapq::util::rng::Rng;

fn rand_weights(rng: &mut Rng, rows: usize, c: usize) -> Tensor {
    Tensor::new(
        vec![rows, c],
        (0..rows * c).map(|_| (rng.normal() * 0.3) as f32).collect(),
    )
}

#[test]
fn prune_then_quantize_preserves_sparsity_any_alg_any_ratio() {
    forall(
        "quantize never resurrects or kills weights",
        |r| {
            let rows = 4 + r.below(24);
            let c = 2 + r.below(16);
            (
                rand_weights(r, rows, c),
                r.below(7),
                r.range(0.0, 0.9),
                2 + r.below(7) as u32,
            )
        },
        |(w0, alg_i, ratio, bits)| {
            let mut w = w0.clone();
            let sal = Tensor::full(w.shape.clone(), 1.0);
            let chsq = vec![1.0f32; w.out_channels(false)];
            let mut rng = Rng::new(3);
            let mut ctx = PruneCtx { saliency: &sal, chsq: &chsq, dwconv: false, rng: &mut rng };
            let res = prune(&mut w, PruneAlg::from_index(*alg_i), *ratio, &mut ctx);
            let s_before = w.sparsity();
            quantize_weights(&mut w, *bits);
            (w.sparsity() - s_before).abs() < 1e-7 && res.sparsity >= 0.0
        },
    );
}

#[test]
fn coarse_masks_are_whole_channels() {
    forall(
        "every coarse-pruned channel is fully zero, others fully alive",
        |r| {
            let rows = 4 + r.below(12);
            let c = 3 + r.below(12);
            (rand_weights(r, rows, c), r.range(0.1, 0.8))
        },
        |(w0, ratio)| {
            let mut w = w0.clone();
            let sal = Tensor::full(w.shape.clone(), 1.0);
            let chsq = vec![1.0f32; w.out_channels(false)];
            let mut rng = Rng::new(7);
            let mut ctx = PruneCtx { saliency: &sal, chsq: &chsq, dwconv: false, rng: &mut rng };
            let res = prune(&mut w, PruneAlg::L1Ranked, *ratio, &mut ctx);
            let dead: std::collections::HashSet<usize> =
                res.channels.unwrap().into_iter().collect();
            let c = w.out_channels(false);
            let l1 = w.channel_l1(false);
            (0..c).all(|ch| {
                if dead.contains(&ch) {
                    l1[ch] == 0.0
                } else {
                    l1[ch] > 0.0 || w0.channel_l1(false)[ch] == 0.0
                }
            })
        },
    );
}

#[test]
fn energy_model_dominance_coarse_ge_fine_everywhere() {
    let rq = RqTable::compute(1200, 11);
    forall(
        "eq(8) energy <= eq(7) energy at equal sparsity/bits",
        |r| {
            let hw = 4 + r.below(20);
            let ci = 2 + r.below(48);
            let co = 2 + r.below(48);
            let model = EnergyModel::new(
                vec![LayerDims::conv(hw, hw, ci, hw, hw, co, 3, 1)],
                Accel::default(),
                rq.clone(),
            );
            (model, r.uniform(), 2 + r.below(7) as u32)
        },
        |(model, s, bits)| {
            let fine = Compression { sparsity: *s, coarse: false, bits: *bits };
            let coarse = Compression { sparsity: *s, coarse: true, bits: *bits };
            model.layer(0, &coarse) <= model.layer(0, &fine) + 1e-9
        },
    );
}

#[test]
fn latency_never_below_compute_roofline() {
    let acc = Accel::default();
    forall(
        "cycles >= effective MACs / PEs",
        |r| {
            let hw = 2 + r.below(24);
            let c = 2 + r.below(64);
            (
                LayerDims::conv(hw, hw, c, hw, hw, c, 3, 1),
                r.uniform(),
                r.uniform() < 0.5,
            )
        },
        |(d, s, coarse)| {
            let m = map_layer(d, &acc);
            let cfg = Compression { sparsity: *s, coarse: *coarse, bits: 8 };
            let cycles = hapq::hw::latency::layer_cycles(&m, &acc, &cfg);
            let eff = if *coarse { 1.0 - s } else { 1.0 };
            cycles + 1e-9 >= m.macs as f64 * eff / (acc.pe_rows * acc.pe_cols) as f64
        },
    );
}

#[test]
fn dataflow_mapping_deterministic_and_fits_buffer() {
    let acc = Accel::default();
    forall(
        "map_layer is deterministic and within compulsory bounds",
        |r| LayerDims::conv(
            2 + r.below(30), 2 + r.below(30), 1 + r.below(96),
            2 + r.below(30), 2 + r.below(30), 1 + r.below(96),
            1 + 2 * r.below(3), 1 + r.below(2),
        ),
        |d| {
            // normalise: oh/ow derived from ih/iw under SAME padding
            let d = LayerDims::conv(
                d.ih, d.iw, d.ci,
                d.ih.div_ceil(d.stride), d.iw.div_ceil(d.stride), d.co,
                d.k, d.stride,
            );
            let m1 = map_layer(&d, &acc);
            let m2 = map_layer(&d, &acc);
            m1.dram == m2.dram
                && m1.gb == m2.gb
                && m1.dram >= d.ifmap() + d.weights() + d.ofmap()
        },
    );
}

#[test]
fn builtin_targets_cost_monotone_and_bounded() {
    use hapq::hw::target::{HwTarget, BUILTIN_TARGETS};
    // the seed-7 table is the one energy.rs's bit-monotonicity test
    // pins; 2-bit steps stay above the MAC-sim sampling noise floor
    let rq = RqTable::compute(1500, 7);
    forall(
        "per-target gains monotone in sparsity/bits, bounded, shares sum to 1",
        |r| {
            let hw = 4 + r.below(12);
            let ci = 2 + r.below(24);
            let co = 2 + r.below(24);
            let dims = vec![
                LayerDims::conv(hw, hw, ci, hw, hw, co, 3, 1),
                LayerDims::fc(64, 10),
            ];
            (
                dims,
                r.below(BUILTIN_TARGETS.len()),
                r.range(0.0, 0.8),
                2 + r.below(5) as u32,
                r.range(0.05, 0.2),
            )
        },
        |(dims, ti, s, b, ds)| {
            let t = HwTarget::builtin(BUILTIN_TARGETS[*ti]).unwrap();
            let m = EnergyModel::for_target(dims.clone(), &t, rq.clone());
            let n = m.n_layers();
            let uni = |s: f64, coarse: bool, bits: u32| {
                vec![Compression { sparsity: s, coarse, bits }; n]
            };
            // energy & latency gains nondecreasing in structured sparsity
            let g_lo = m.gain(&uni(*s, true, *b));
            let g_hi = m.gain(&uni((*s + *ds).min(1.0), true, *b));
            let lg_lo = m.latency_gain(&uni(*s, true, *b));
            let lg_hi = m.latency_gain(&uni((*s + *ds).min(1.0), true, *b));
            // energy gain nonincreasing in bits (2-bit step)
            let g_b = m.gain(&uni(0.0, false, *b));
            let g_b2 = m.gain(&uni(0.0, false, *b + 2));
            // all gains bounded in [0, 1]
            let bounded =
                |g: f64| (-1e-9..=1.0 + 1e-9).contains(&g);
            // per-layer dense shares sum to 1
            let rows = hapq::hw::report::breakdown(&m, &uni(*s, true, *b));
            let share: f64 = rows.iter().map(|r| r.dense_share).sum();
            g_hi + 1e-9 >= g_lo
                && lg_hi + 1e-9 >= lg_lo
                && g_b + 1e-9 >= g_b2
                && [g_lo, g_hi, lg_lo, lg_hi, g_b, g_b2].iter().all(|&g| bounded(g))
                && (share - 1.0).abs() < 1e-9
        },
    );
}

#[test]
fn cost_cache_matches_scratch_bitwise_under_invalidates() {
    use hapq::hw::cost::{CostCache, CostModel};
    use hapq::hw::target::{HwTarget, BUILTIN_TARGETS};
    let rq = RqTable::compute(600, 5);
    for name in BUILTIN_TARGETS {
        let t = HwTarget::builtin(name).unwrap();
        let dims = vec![
            LayerDims::conv(12, 12, 8, 12, 12, 16, 3, 1),
            LayerDims::conv(12, 12, 16, 6, 6, 16, 3, 2),
            LayerDims::fc(128, 10),
        ];
        let em = EnergyModel::for_target(dims, &t, rq.clone());
        let mut scratch = em.clone();
        let mut cache = CostCache::new(em);
        let n = scratch.n_layers();
        let mut rng = Rng::new(0x7A57);
        let mut cfgs = vec![Compression::dense(); n];
        for step in 0..200 {
            match rng.below(5) {
                0..=2 => {
                    let l = rng.below(n);
                    cfgs[l] = Compression {
                        sparsity: rng.uniform(),
                        coarse: rng.uniform() < 0.5,
                        bits: 2 + rng.below(7) as u32,
                    };
                }
                3 => cache.invalidate(rng.below(n)),
                _ => cache.invalidate_all(),
            }
            assert_eq!(
                cache.energy_gain(&cfgs).to_bits(),
                CostModel::energy_gain(&mut scratch, &cfgs).to_bits(),
                "{name}: energy gain diverged at step {step}"
            );
            assert_eq!(
                cache.latency_gain(&cfgs).to_bits(),
                CostModel::latency_gain(&mut scratch, &cfgs).to_bits(),
                "{name}: latency gain diverged at step {step}"
            );
        }
        assert!(
            cache.reused() > 0 && cache.recomputed() > 0,
            "{name}: the walk must exercise both cache paths"
        );
    }
}

#[test]
fn reward_lut_monotone_in_gain_within_target_region() {
    let lut = hapq::env::lut::RewardLut::paper();
    forall(
        "inside loss<10%, more gain never reduces reward",
        |r| (r.range(0.0, 0.099), r.range(0.06, 0.9), r.range(0.02, 0.09)),
        |&(loss, g, dg)| {
            lut.reward(loss, (g + dg).min(1.0)) + 1e-12 >= lut.reward(loss, g)
        },
    );
}

#[test]
fn json_roundtrip_arbitrary_trees() {
    use hapq::io::json::{arr, num, obj, parse, s, Value};
    forall(
        "emit->parse is identity on generated trees",
        |r| {
            fn gen(r: &mut Rng, depth: usize) -> Value {
                match if depth == 0 { r.below(3) } else { r.below(5) } {
                    0 => num((r.normal() * 100.0 * 8.0).round() / 8.0),
                    1 => s(&format!("k{}", r.below(1000))),
                    2 => Value::Bool(r.uniform() < 0.5),
                    3 => arr((0..r.below(4)).map(|_| gen(r, depth - 1)).collect()),
                    _ => obj(vec![
                        ("a", gen(r, depth - 1)),
                        ("b", gen(r, depth - 1)),
                    ]),
                }
            }
            gen(r, 3)
        },
        |v| parse(&v.to_string()).map(|p| p == *v).unwrap_or(false),
    );
}

// ---------------------------------------------------------------------------
// Activation-grid properties (quant_params / fake_quant edge cases):
// the int kernel's bit-exactness rests on these invariants, so they
// are pinned here against random grids.

#[test]
fn quant_params_clamps_bits_into_the_paper_range() {
    use hapq::runtime::native::quant_params;
    forall(
        "bits outside [2, 8] clamp to the boundary grids",
        |r| (r.range(-3.0, 15.0) as f32, r.range(1e-3, 4.0) as f32, r.uniform() < 0.5),
        |&(bits, scale, signed)| {
            let got = quant_params(bits, scale, signed);
            let clamped = quant_params(bits.round().clamp(2.0, 8.0), scale, signed);
            // bits = 1 (paper's forbidden precision) behaves as 2 bits
            let one = quant_params(1.0, scale, signed);
            let two = quant_params(2.0, scale, signed);
            got == clamped && one == two
        },
    );
}

#[test]
fn quant_params_grid_shape_signed_vs_unsigned() {
    use hapq::runtime::native::quant_params;
    forall(
        "signed grids are symmetric, unsigned start at zero",
        |r| (2.0 + r.below(7) as f32, r.range(1e-3, 4.0) as f32),
        |&(bits, scale)| {
            let (lo_u, hi_u, step_u) = quant_params(bits, scale, false);
            let (lo_s, hi_s, step_s) = quant_params(bits, scale, true);
            lo_u == 0.0
                && hi_u > 0.0
                && lo_s == -hi_s
                && step_u > 0.0
                && step_s > 0.0
                // the signed grid spans twice the range with the same
                // level count, so its step is exactly doubled
                && step_s == 2.0 * step_u
        },
    );
}

#[test]
fn fake_quant_outputs_are_grid_codes_exactly() {
    use hapq::quant::QuantGrid;
    use hapq::runtime::native::{fake_quant, quant_params};
    forall(
        "every snapped value is value(code) bitwise, codes in range",
        |r| {
            let bits = 2.0 + r.below(7) as f32;
            let scale = r.range(1e-3, 4.0) as f32;
            let signed = r.uniform() < 0.5;
            let vals: Vec<f32> =
                (0..1 + r.below(32)).map(|_| (r.normal() * 2.0) as f32).collect();
            (bits, scale, signed, vals)
        },
        |(bits, scale, signed, vals)| {
            let (lo, hi, step) = quant_params(*bits, *scale, *signed);
            let grid = QuantGrid::new(lo, hi, step);
            let levels = grid.levels() as i16;
            let mut snapped = vals.clone();
            fake_quant(&mut snapped, lo, hi, step);
            vals.iter().zip(&snapped).all(|(&x, &y)| {
                let code = grid.code(x);
                (0..=levels).contains(&code) && grid.value(code) == y
            })
        },
    );
}

#[test]
fn fake_quant_clamps_and_fixes_boundary_values() {
    use hapq::quant::QuantGrid;
    use hapq::runtime::native::{fake_quant, quant_params};
    forall(
        "lo is a fixed point; beyond-range values snap like the boundary",
        |r| {
            (
                2.0 + r.below(7) as f32,
                r.range(1e-3, 4.0) as f32,
                r.uniform() < 0.5,
                (r.range(0.1, 3.0)) as f32,
            )
        },
        |&(bits, scale, signed, overshoot)| {
            let (lo, hi, step) = quant_params(bits, scale, signed);
            let grid = QuantGrid::new(lo, hi, step);
            // the lower clip point is exactly representable (code 0)
            let mut v = [lo, hi + overshoot, lo - overshoot, hi];
            fake_quant(&mut v, lo, hi, step);
            v[0] == lo && v[1] == grid.snap(hi) && v[2] == lo && v[3] == grid.snap(hi)
        },
    );
}

#[test]
fn grid_code_value_roundtrip_over_all_levels() {
    use hapq::quant::QuantGrid;
    use hapq::runtime::native::quant_params;
    forall(
        "code(value(n)) == n for every level of every activation grid",
        |r| (2.0 + r.below(7) as f32, r.range(1e-3, 4.0) as f32, r.uniform() < 0.5),
        |&(bits, scale, signed)| {
            let (lo, hi, step) = quant_params(bits, scale, signed);
            let grid = QuantGrid::new(lo, hi, step);
            let levels = grid.levels();
            levels == (bits.exp2() - 1.0) as usize
                && (0..=levels).all(|n| grid.code(grid.value(n as i16)) == n as i16)
        },
    );
}

#[test]
fn fake_quant_is_monotone() {
    use hapq::runtime::native::{fake_quant, quant_params};
    forall(
        "x <= y implies snap(x) <= snap(y)",
        |r| {
            let a = (r.normal() * 2.0) as f32;
            let b = (r.normal() * 2.0) as f32;
            (
                2.0 + r.below(7) as f32,
                r.range(1e-3, 4.0) as f32,
                r.uniform() < 0.5,
                a.min(b),
                a.max(b),
            )
        },
        |&(bits, scale, signed, x, y)| {
            let (lo, hi, step) = quant_params(bits, scale, signed);
            let mut v = [x, y];
            fake_quant(&mut v, lo, hi, step);
            v[0] <= v[1]
        },
    );
}

// ---------------------------------------------------------------------------
// PackedMat::pack properties (the int kernel's pack-time contract):
// pack drops exactly the all-zero rows/columns of the dense operand,
// `live_col_count` stays consistent with the storage shape, and the
// packed GEMM agrees bitwise with the dense f32 matmul on the
// degenerate 1×N / N×1 shapes the blocked kernel's remainder paths see.

#[test]
fn packed_mat_pack_drops_exactly_the_zero_planes() {
    use hapq::nn::mat::PackedMat;
    forall(
        "live planes mirror the dense operand; storage is consistent",
        |r| {
            let k = 1 + r.below(12);
            let n = 1 + r.below(12);
            let mut data = vec![0.0f32; k * n];
            for v in data.iter_mut() {
                if r.uniform() < 0.5 {
                    *v = (r.normal() as f32) * 0.5;
                }
            }
            // kill a few whole rows/columns so pruned planes appear;
            // sometimes kill everything (the all-zero-plane edge)
            for _ in 0..r.below(3) {
                let row = r.below(k);
                data[row * n..row * n + n].fill(0.0);
            }
            for _ in 0..r.below(3) {
                let col = r.below(n);
                for kk in 0..k {
                    data[kk * n + col] = 0.0;
                }
            }
            if r.below(12) == 0 {
                data.fill(0.0);
            }
            (k, n, data)
        },
        |(k, n, data)| {
            let (k, n) = (*k, *n);
            let p = PackedMat::pack(k, n, data);
            let want_rows: Vec<u32> = (0..k)
                .filter(|&kk| (0..n).any(|c| data[kk * n + c] != 0.0))
                .map(|x| x as u32)
                .collect();
            let want_cols: Vec<u32> = (0..n)
                .filter(|&c| (0..k).any(|kk| data[kk * n + c] != 0.0))
                .map(|x| x as u32)
                .collect();
            // live_cols is None exactly when every column is live
            let cols_ok = match &p.live_cols {
                None => want_cols.len() == n,
                Some(cols) => *cols == want_cols && want_cols.len() < n,
            };
            // packed storage holds exactly the live intersection,
            // bitwise-equal to the dense source
            let lc = p.live_col_count();
            let d_ok = p.d.len() == want_rows.len() * lc
                && p.live_rows.iter().enumerate().all(|(ri, &kk)| {
                    want_cols.iter().enumerate().all(|(ci, &c)| {
                        p.d[ri * lc + ci].to_bits()
                            == data[kk as usize * n + c as usize].to_bits()
                    })
                });
            p.live_rows == want_rows && cols_ok && lc == want_cols.len() && d_ok
        },
    );
}

#[test]
fn packed_mat_single_live_row_and_column() {
    use hapq::nn::mat::PackedMat;
    forall(
        "one nonzero element packs to a 1x1 plane",
        |r| {
            let k = 1 + r.below(16);
            let n = 1 + r.below(16);
            let ri = r.below(k);
            let ci = r.below(n);
            let v = (0.1 + r.uniform() as f32).copysign(if r.uniform() < 0.5 { -1.0 } else { 1.0 });
            (k, n, ri, ci, v)
        },
        |&(k, n, ri, ci, v)| {
            let mut data = vec![0.0f32; k * n];
            data[ri * n + ci] = v;
            let p = PackedMat::pack(k, n, &data);
            let cols_ok = if n == 1 {
                p.live_cols.is_none() // the single column is live
            } else {
                p.live_cols.as_deref() == Some(&[ci as u32])
            };
            p.live_rows == [ri as u32]
                && cols_ok
                && p.live_col_count() == 1
                && p.d.len() == 1
                && p.d[0].to_bits() == v.to_bits()
        },
    );
}

#[test]
fn packed_code_matmul_matches_dense_on_degenerate_shapes() {
    use hapq::nn::mat::{CodeMat, Mat, PackedMat};
    use hapq::quant::QuantGrid;
    use hapq::runtime::native::quant_params;
    forall(
        "pack + code_matmul == dense matmul bitwise on 1xN and Nx1",
        |r| {
            let bits = 2.0 + r.below(7) as f32;
            let scale = r.range(0.2, 2.0) as f32;
            let k = 1 + r.below(24);
            let long = 1 + r.below(24);
            // (rows, cols): one of the two GEMM dims pinned to 1
            let (rows, cols) = if r.uniform() < 0.5 { (1, long) } else { (long, 1) };
            (bits, scale, rows, k, cols, r.next_u64())
        },
        |&(bits, scale, rows, k, cols, seed)| {
            let (lo, hi, step) = quant_params(bits, scale, false);
            let grid = QuantGrid::new(lo, hi, step);
            let lut = grid.lut().unwrap();
            let mut rng = Rng::new(seed);
            // codes mix structural zeros (-1), grid zeros (0) and live
            // levels — everything the engine's im2col can emit
            let codes = CodeMat {
                r: rows,
                c: k,
                d: (0..rows * k)
                    .map(|_| match rng.below(4) {
                        0 => -1,
                        1 => 0,
                        _ => 1 + rng.below(grid.levels()) as i16,
                    })
                    .collect(),
            };
            let acts = Mat::from_vec(
                rows,
                k,
                codes.d.iter().map(|&c| lut[(c + 1) as usize]).collect(),
            );
            let wdense: Vec<f32> = (0..k * cols)
                .map(|_| if rng.uniform() < 0.4 { 0.0 } else { rng.normal() as f32 * 0.3 })
                .collect();
            let wmat = Mat::from_vec(k, cols, wdense.clone());
            let packed = PackedMat::pack(k, cols, &wdense);
            let dense = acts.matmul(&wmat);
            let bitwise = |m: &Mat| m.d.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            bitwise(&packed.code_matmul(&codes, &lut)) == bitwise(&dense)
                && bitwise(&packed.code_matmul_scalar(&codes, &lut)) == bitwise(&dense)
                && bitwise(&packed.code_matmul_tiled(&codes, &lut, 3)) == bitwise(&dense)
        },
    );
}

#[test]
fn npz_roundtrip_arbitrary_tensors() {
    use hapq::io::npz::{save_npz, Npz};
    let dir = std::env::temp_dir().join("hapq_prop_npz");
    std::fs::create_dir_all(&dir).unwrap();
    forall(
        "save_npz -> Npz::load is identity",
        |r| {
            let n = 1 + r.below(5);
            (0..n)
                .map(|i| {
                    let rows = 1 + r.below(8);
                    let cols = 1 + r.below(8);
                    (
                        format!("t{i}"),
                        Tensor::new(
                            vec![rows, cols],
                            (0..rows * cols).map(|_| r.normal() as f32).collect(),
                        ),
                    )
                })
                .collect::<Vec<_>>()
        },
        |tensors| {
            let path = dir.join("t.npz");
            let refs: Vec<(String, &Tensor)> =
                tensors.iter().map(|(k, t)| (k.clone(), t)).collect();
            save_npz(&path, &refs).unwrap();
            let npz = Npz::load(&path).unwrap();
            tensors
                .iter()
                .all(|(k, t)| npz.tensor(k).map(|got| got == *t).unwrap_or(false))
        },
    );
}
