//! Telemetry golden tests, artifact-free (same in-memory fixture family
//! as `tests/native_backend.rs` / `tests/search_driver.rs`):
//!
//! 1. **Observation-only**: a seeded search with the trace sink enabled
//!    produces a bit-identical `SearchOutcome` (best solution, curve,
//!    eval count) to the same search with tracing off — at threads
//!    {1,4} × kernels {f32,int}.
//! 2. **Determinism**: two traced runs at the same seed produce
//!    identical event sequences modulo the wall-clock-only `ts`/`dur`
//!    fields (`Trace::canonical`) — under the static scheduler at any
//!    thread count, and under the stealing scheduler single-threaded
//!    (multi-thread steal claim order is timing-dependent by design,
//!    so only the run *results* are pinned there, not the trace).
//! 3. **Schema**: the JSONL file carries the `meta` header, per-step /
//!    per-episode search events, every env phase span and worker-tagged
//!    exec spans; the Chrome export holds ≥ 1 complete event per phase.
//! 4. **Registry**: `metrics_snapshot` over the real stat sources
//!    (`PhaseTimers`, `RuntimeStats`, `CostCache`) round-trips JSON.

use std::path::PathBuf;
use std::sync::Mutex;

use hapq::baselines;
use hapq::env::{CompressionEnv, Solution};
use hapq::hw::energy::EnergyModel;
use hapq::hw::mac_sim::RqTable;
use hapq::hw::Accel;
use hapq::io::json;
use hapq::model::{ModelArch, Weights};
use hapq::runtime::{
    EvalData, InferenceSession, KernelKind, MemoConfig, NativeBackend, SchedKind,
};
use hapq::search::{SearchDriver, SearchOutcome};
use hapq::telemetry::{self, analyze};
use hapq::tensor::Tensor;

/// The trace sink is process-global: tests touching it must not overlap.
static GUARD: Mutex<()> = Mutex::new(());

const FIX1: &str = r#"{
  "name": "fix1", "dataset": "synth-fix", "input": [2, 2, 1], "classes": 2,
  "batch": 2,
  "layers": [
    {"name": "c1", "op": "conv", "inputs": ["input"], "k": 1, "stride": 1,
     "relu": true, "in_shape": [2,2,1], "out_shape": [2,2,1], "in_ch": 1,
     "out_ch": 1},
    {"name": "gap", "op": "gap", "inputs": ["c1"], "in_shape": [2,2,1],
     "out_shape": [1]},
    {"name": "f1", "op": "fc", "inputs": ["gap"], "relu": false,
     "in_shape": [1], "out_shape": [2], "in_ch": 1, "out_ch": 2}
  ],
  "prunable": ["c1", "f1"],
  "dep_groups": [],
  "act_scales": [0.3533568904593639, 0.3533568904593639],
  "act_signed": [false, false],
  "acc_int8": 1.0, "n_params": 5
}"#;

const ENV_SEED: u64 = 7;

fn mk_env(seed: u64, threads: usize, kernel: KernelKind, sched: SchedKind) -> CompressionEnv {
    let arch = ModelArch::from_json(&json::parse(FIX1).unwrap()).unwrap();
    let weights = Weights {
        w: vec![
            Tensor::new(vec![1, 1, 1, 1], vec![2.0]),
            Tensor::new(vec![1, 2], vec![1.0, -1.0]),
        ],
        b: vec![
            Tensor::new(vec![1], vec![-0.4]),
            Tensor::new(vec![2], vec![0.0, 0.25]),
        ],
        sal: vec![Tensor::full(vec![1, 1, 1, 1], 1.0), Tensor::full(vec![1, 2], 1.0)],
        chsq: vec![vec![1.0], vec![1.0, 1.0]],
    };
    let images = Tensor::new(
        vec![4, 2, 2, 1],
        vec![
            0.2, 0.4, 0.6, 0.8, //
            0.05, 0.1, 0.15, 0.1, //
            0.7, 0.7, 0.2, 0.3, //
            0.9, 0.8, 0.7, 0.6,
        ],
    );
    let labels = vec![0i64, 1, 0, 0];
    let data = EvalData::from_arrays(&arch, &images, &labels, 16, arch.batch).unwrap();
    let backend =
        NativeBackend::with_sched(&arch, data, threads, kernel, MemoConfig::default(), sched)
            .unwrap();
    let session = InferenceSession::from_backend(Box::new(backend));
    let energy = EnergyModel::new(
        arch.layer_dims().unwrap(),
        Accel::default(),
        RqTable::compute(300, 3),
    );
    CompressionEnv::new(arch, weights, energy, session, seed).unwrap()
}

/// One short, fully deterministic search (ASQ-J: no agent nets, fast in
/// debug builds) whose outcome the bit-identity assertions compare.
fn run_search(threads: usize, kernel: KernelKind, sched: SchedKind) -> SearchOutcome {
    let mut env = mk_env(ENV_SEED, threads, kernel, sched);
    let cfg = baselines::asqj::AsqjConfig { iters: 6, rho: 0.15, seed: 0 };
    let mut strategy = baselines::asqj::AsqjStrategy::new(&cfg, env.n_layers());
    SearchDriver::plain().run(&mut env, &mut strategy).unwrap()
}

fn assert_sol_bits_eq(a: &Solution, b: &Solution, what: &str) {
    assert_eq!(a.per_layer.len(), b.per_layer.len(), "{what}: per_layer len");
    for (x, y) in a.per_layer.iter().zip(&b.per_layer) {
        assert_eq!(x.sparsity.to_bits(), y.sparsity.to_bits(), "{what}: sparsity");
        assert_eq!(x.bits, y.bits, "{what}: bits");
    }
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{what}: accuracy");
    assert_eq!(a.acc_loss.to_bits(), b.acc_loss.to_bits(), "{what}: acc_loss");
    assert_eq!(a.energy_gain.to_bits(), b.energy_gain.to_bits(), "{what}: energy_gain");
    assert_eq!(a.latency_gain.to_bits(), b.latency_gain.to_bits(), "{what}: latency_gain");
    assert_eq!(a.reward.to_bits(), b.reward.to_bits(), "{what}: reward");
}

fn assert_outcome_bits_eq(a: &SearchOutcome, b: &SearchOutcome, what: &str) {
    assert_sol_bits_eq(a.best.as_ref().unwrap(), b.best.as_ref().unwrap(), what);
    assert_eq!(a.curve.len(), b.curve.len(), "{what}: curve len");
    for (x, y) in a.curve.iter().zip(&b.curve) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: curve");
    }
    assert_eq!(a.evals, b.evals, "{what}: evals");
    assert_eq!(a.episodes_run, b.episodes_run, "{what}: episodes");
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hapq-telemetry-{name}-{}.jsonl", std::process::id()))
}

/// Golden + determinism matrix: for every (threads, kernel, sched)
/// cell, an untraced run, then two traced runs — results bitwise
/// identical across all three (and across the two schedulers), traces
/// canonically identical across the pair wherever the event layout is
/// deterministic: static at any thread count, steal single-threaded.
/// Multi-thread steal claim order is timing-dependent by design, so
/// that cell pins results + schema only.
#[test]
fn tracing_is_observation_only_and_deterministic() {
    let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    for threads in [1usize, 4] {
        for kernel in [KernelKind::F32, KernelKind::Int] {
            let mut outcomes = Vec::new();
            for sched in [SchedKind::Static, SchedKind::Steal] {
                let what =
                    format!("threads={threads} kernel={} sched={}", kernel.name(), sched.name());
                let plain = run_search(threads, kernel, sched);

                let mut canon = Vec::new();
                for pass in 0..2 {
                    let path =
                        tmp(&format!("t{threads}-{}-{}-{pass}", kernel.name(), sched.name()));
                    let _ = std::fs::remove_file(&path);
                    telemetry::init(&path);
                    let traced = run_search(threads, kernel, sched);
                    let written = telemetry::finish().unwrap().expect("sink enabled");
                    assert_eq!(written, path);
                    // observation-only: run results do not move with tracing
                    assert_outcome_bits_eq(&plain, &traced, &what);
                    canon.push(analyze::load(&path).unwrap().canonical());
                    let _ = std::fs::remove_file(&path);
                }
                assert!(canon[0].contains("\"kind\":\"episode\""), "{what}: no episode events");
                // determinism: same seed ⇒ same events modulo ts/dur —
                // except multi-thread steal, where which worker claims
                // which shard (and therefore which thread tag carries
                // each exec span) is a timing race on purpose
                if sched == SchedKind::Static || threads == 1 {
                    assert_eq!(canon[0], canon[1], "{what}: canonical trace diverged");
                }
                outcomes.push(plain);
            }
            // the scheduler itself must be invisible in the results
            assert_outcome_bits_eq(
                &outcomes[0],
                &outcomes[1],
                &format!("threads={threads} kernel={} static-vs-steal", kernel.name()),
            );
        }
    }
}

#[test]
fn trace_schema_and_chrome_export_cover_every_phase() {
    let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    let path = tmp("schema");
    let _ = std::fs::remove_file(&path);
    telemetry::init(&path);
    let outcome = run_search(4, KernelKind::Int, SchedKind::Steal);
    telemetry::finish().unwrap().expect("sink enabled");

    let text = std::fs::read_to_string(&path).unwrap();
    let meta = json::parse(text.lines().next().unwrap()).unwrap();
    assert_eq!(meta.req("kind").unwrap().as_str().unwrap(), "meta");
    assert_eq!(meta.req("schema").unwrap().as_usize().unwrap() as u64, telemetry::SCHEMA);

    let tr = analyze::load(&path).unwrap();
    let kind_count = |k: &str| {
        tr.events
            .iter()
            .filter(|v| v.get("kind").and_then(|x| x.as_str().ok()) == Some(k))
            .count()
    };
    // asqj on the 2-prunable-layer fixture: 6 episodes × 2 steps
    assert_eq!(kind_count("episode"), 6);
    assert_eq!(kind_count("step"), 12);
    let span_names: Vec<&str> = tr
        .events
        .iter()
        .filter(|v| v.get("kind").and_then(|x| x.as_str().ok()) == Some("span"))
        .filter_map(|v| v.get("name").and_then(|x| x.as_str().ok()))
        .collect();
    for phase in ["env.prune", "env.quant", "env.hw", "env.infer", "env.step", "exec.shard"] {
        assert!(span_names.contains(&phase), "missing {phase} spans: {span_names:?}");
    }
    // exec spans come from pool workers, under their own thread tag
    assert!(
        tr.events.iter().any(|v| {
            v.get("thread")
                .and_then(|x| x.as_str().ok())
                .map_or(false, |t| t.starts_with("worker"))
        }),
        "no worker-tagged events"
    );
    // the cost cache reports hit/miss counters through the env
    assert!(
        tr.events.iter().any(|v| {
            v.get("name").and_then(|x| x.as_str().ok()) == Some("hw.cache.reused")
        }),
        "no cost-cache counter events"
    );
    // the scheduler reports per-worker steal/shard-count events and
    // the engine reports the per-query imbalance gauge
    for name in ["exec.steal", "exec.worker_shards", "exec.imbalance"] {
        assert!(
            tr.events.iter().any(|v| {
                v.get("name").and_then(|x| x.as_str().ok()) == Some(name)
            }),
            "no {name} events"
        );
    }

    // the human renderings carry the reward curve / rollup content
    let table = tr.reward_table().unwrap();
    assert!(table.lines().count() >= 7, "6 episode rows + header: {table}");
    let rollup = tr.phase_rollup().unwrap();
    assert!(rollup.contains("env.infer"), "{rollup}");
    let hot = tr.hottest_layers(5).unwrap();
    assert!(hot.lines().count() >= 3, "both fixture layers rank: {hot}");

    // Chrome export: valid JSON, ≥ 1 complete ("X") event per env phase
    let chrome = tr.chrome().unwrap();
    let back = json::parse(&chrome.to_string()).unwrap();
    let evs = back.req("traceEvents").unwrap().as_arr().unwrap();
    for phase in ["env.prune", "env.quant", "env.hw", "env.infer"] {
        assert!(
            evs.iter().any(|e| {
                e.get("ph").and_then(|p| p.as_str().ok()) == Some("X")
                    && e.get("name").and_then(|n| n.as_str().ok()) == Some(phase)
            }),
            "chrome export missing complete {phase} event"
        );
    }
    assert!(outcome.best.is_some());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn metrics_snapshot_reads_the_real_stat_sources() {
    let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    let mut env = mk_env(ENV_SEED, 2, KernelKind::Int);
    let actions: Vec<hapq::env::Action> = (0..env.n_layers())
        .map(|l| hapq::env::Action { ratio: 0.3, bits: 0.8, alg: l % 7 })
        .collect();
    env.evaluate_config(&actions).unwrap();
    let stats = env.session_stats();
    let snap = telemetry::metrics_snapshot(&[&env.timers, &stats, &env.cost]);
    // the snapshot is exactly what `hapq perf --json` prints — it must
    // survive its own serialisation and carry all three sources
    let back = json::parse(&snap.to_string()).unwrap();
    assert_eq!(back.req("schema").unwrap().as_usize().unwrap() as u64, telemetry::SCHEMA);
    let counters = back.req("counters").unwrap();
    assert!(counters.req("env.steps").unwrap().as_usize().unwrap() > 0);
    assert!(counters.req("hw.queries").unwrap().as_usize().unwrap() > 0);
    assert!(counters.req("exec.layers_computed").unwrap().as_usize().unwrap() > 0);
    let gauges = back.req("gauges").unwrap();
    assert!(gauges.req("env.infer_s").unwrap().as_f64().unwrap() >= 0.0);
    assert_eq!(gauges.req("exec.threads").unwrap().as_usize().unwrap(), 2);
    let labels = back.req("labels").unwrap();
    assert_eq!(labels.req("exec.kernel").unwrap().as_str().unwrap(), "int");
    assert!(!labels.req("hw.target").unwrap().as_str().unwrap().is_empty());
}
