//! Golden-parity guarantees for the pluggable hardware-target
//! subsystem, with NO artifacts needed (same pattern as
//! `tests/search_driver.rs`):
//!
//! 1. **Cost-math parity**: this file carries a verbatim in-test copy
//!    of the PRE-REFACTOR cost computation (the hardcoded
//!    `Accel::default()` energy/latency path) and asserts the
//!    refactored `eyeriss-64` target reproduces every per-layer
//!    energy, total, gain, cycle count and breakdown row
//!    **bit-identically**.
//! 2. **Search parity**: a search run on an env built via the
//!    `eyeriss-64` target is bit-identical to one built via the
//!    historical `EnergyModel::new(dims, Accel::default(), rq)`
//!    constructor, and every `StepResult` gain matches the golden
//!    math recomputed from the applied configs.
//! 3. Profile pinning: the `eyeriss-64` built-in carries exactly the
//!    pre-refactor `Accel::default()` numbers.

use hapq::baselines;
use hapq::env::{Action, CompressionEnv};
use hapq::hw::dataflow::{map_layer, LayerDims, Mapping};
use hapq::hw::energy::{Compression, EnergyModel};
use hapq::hw::mac_sim::RqTable;
use hapq::hw::target::{ComputeScaling, HwTarget, BUILTIN_TARGETS};
use hapq::hw::Accel;
use hapq::io::json;
use hapq::model::{ModelArch, Weights};
use hapq::runtime::{EvalData, InferenceSession, NativeBackend};
use hapq::search::SearchDriver;
use hapq::tensor::Tensor;
use hapq::util::rng::Rng;

// ---------------------------------------------------------------------------
// Golden reference — a verbatim copy of the pre-refactor cost
// computation (hw/energy.rs + hw/latency.rs before the target
// subsystem existed), hardcoded to `Accel::default()`. Do NOT
// "simplify" this to call the refactored code; its whole value is
// being the historical math.

struct GoldenModel {
    acc: Accel,
    rq: RqTable,
    layers: Vec<(LayerDims, Mapping, f64, f64)>,
}

impl GoldenModel {
    fn new(dims: Vec<LayerDims>, rq: RqTable) -> Self {
        let acc = Accel::default();
        let layers = dims
            .into_iter()
            .map(|d| {
                let m = map_layer(&d, &acc);
                let e_mem = m.mem_energy(&acc);
                let e_comp = m.macs as f64 * acc.e_mac;
                (d, m, e_mem, e_comp)
            })
            .collect();
        GoldenModel { acc, rq, layers }
    }

    fn dense_layer(&self, l: usize) -> f64 {
        self.layers[l].2 + self.layers[l].3
    }

    fn layer(&self, l: usize, cfg: &Compression) -> f64 {
        let (_, _, e_mem, e_comp) = self.layers[l];
        let s = cfg.sparsity.clamp(0.0, 1.0);
        let rq = self.rq.rq(cfg.bits, cfg.bits);
        let (r_mem, r_pruned, r_unpruned) = if cfg.coarse {
            (1.0 - s, 0.0, (1.0 - s) * rq) // eq (8)
        } else {
            (1.0, self.rq.p_fg * s, (1.0 - s) * rq) // eq (7)
        };
        e_mem * r_mem + e_comp * (r_pruned + r_unpruned)
    }

    fn total(&self, cfgs: &[Compression]) -> f64 {
        cfgs.iter().enumerate().map(|(l, c)| self.layer(l, c)).sum()
    }

    fn baseline(&self) -> f64 {
        (0..self.layers.len()).map(|l| self.dense_layer(l)).sum()
    }

    fn gain(&self, cfgs: &[Compression]) -> f64 {
        1.0 - self.total(cfgs) / self.baseline()
    }

    /// Verbatim pre-refactor `latency::layer_cycles`.
    fn layer_cycles(&self, m: &Mapping, cfg: &Compression) -> f64 {
        let pes = (self.acc.pe_rows * self.acc.pe_cols) as f64;
        let util = 0.7;
        let s = cfg.sparsity.clamp(0.0, 1.0);
        let (mac_factor, mem_factor) = if cfg.coarse {
            (1.0 - s, 1.0 - s)
        } else {
            (1.0, 1.0)
        };
        let t_comp = m.macs as f64 * mac_factor / (pes * util);
        let t_mem = m.dram as f64 * mem_factor / 0.4;
        t_comp.max(t_mem)
    }

    fn cycles(&self, cfgs: &[Compression]) -> f64 {
        self.layers
            .iter()
            .zip(cfgs)
            .map(|((_, m, _, _), c)| self.layer_cycles(m, c))
            .sum()
    }

    fn latency_gain(&self, cfgs: &[Compression]) -> f64 {
        let dense = vec![Compression::dense(); self.layers.len()];
        1.0 - self.cycles(cfgs) / self.cycles(&dense)
    }
}

fn mixed_dims() -> Vec<LayerDims> {
    vec![
        LayerDims::conv(16, 16, 3, 16, 16, 16, 3, 1),
        LayerDims::conv(16, 16, 16, 8, 8, 32, 3, 2),
        LayerDims::dwconv(8, 8, 32, 8, 8, 3, 1),
        LayerDims::fc(512, 10),
    ]
}

fn random_cfg(rng: &mut Rng) -> Compression {
    Compression {
        sparsity: rng.uniform(),
        coarse: rng.uniform() < 0.5,
        bits: 2 + rng.below(7) as u32,
    }
}

// ---------------------------------------------------------------------------
// 1. Cost-math parity, bit for bit

#[test]
fn eyeriss64_target_bit_identical_to_prerefactor_cost_math() {
    let rq = RqTable::compute(800, 3);
    let golden = GoldenModel::new(mixed_dims(), rq.clone());
    let target = HwTarget::builtin("eyeriss-64").unwrap();
    let em = EnergyModel::for_target(mixed_dims(), &target, rq);
    assert_eq!(em.baseline().to_bits(), golden.baseline().to_bits());

    let mut rng = Rng::new(11);
    for _ in 0..200 {
        let cfgs: Vec<Compression> =
            (0..em.n_layers()).map(|_| random_cfg(&mut rng)).collect();
        for (l, c) in cfgs.iter().enumerate() {
            assert_eq!(
                em.layer(l, c).to_bits(),
                golden.layer(l, c).to_bits(),
                "layer {l} energy diverged for {c:?}"
            );
        }
        assert_eq!(em.total(&cfgs).to_bits(), golden.total(&cfgs).to_bits());
        assert_eq!(em.gain(&cfgs).to_bits(), golden.gain(&cfgs).to_bits());
        assert_eq!(em.cycles(&cfgs).to_bits(), golden.cycles(&cfgs).to_bits());
        assert_eq!(
            em.latency_gain(&cfgs).to_bits(),
            golden.latency_gain(&cfgs).to_bits()
        );
    }
}

#[test]
fn hw_breakdown_on_eyeriss64_matches_prerefactor_rows() {
    let rq = RqTable::compute(800, 3);
    let golden = GoldenModel::new(mixed_dims(), rq.clone());
    let target = HwTarget::builtin("eyeriss-64").unwrap();
    let em = EnergyModel::for_target(mixed_dims(), &target, rq);

    let mut rng = Rng::new(29);
    let cfgs: Vec<Compression> =
        (0..em.n_layers()).map(|_| random_cfg(&mut rng)).collect();
    let rows = hapq::hw::report::breakdown(&em, &cfgs);
    assert_eq!(rows.len(), golden.layers.len());
    let baseline = golden.baseline();
    for (l, r) in rows.iter().enumerate() {
        // verbatim pre-refactor report.rs row math
        let e_dense = golden.dense_layer(l);
        let e_c = golden.layer(l, &cfgs[l]);
        assert_eq!(r.macs, golden.layers[l].1.macs);
        assert_eq!(r.dram, golden.layers[l].1.dram);
        assert_eq!(r.e_dense.to_bits(), e_dense.to_bits());
        assert_eq!(r.e_compressed.to_bits(), e_c.to_bits());
        assert_eq!(r.dense_share.to_bits(), (e_dense / baseline).to_bits());
        assert_eq!(
            r.layer_gain.to_bits(),
            (1.0 - e_c / e_dense.max(1e-12)).to_bits()
        );
        assert_eq!(
            r.cycles.to_bits(),
            golden.layer_cycles(&golden.layers[l].1, &cfgs[l]).to_bits()
        );
    }
}

// ---------------------------------------------------------------------------
// 2. Search parity on the synthetic fixture env (no artifacts)

const FIX1: &str = r#"{
  "name": "fix1", "dataset": "synth-fix", "input": [2, 2, 1], "classes": 2,
  "batch": 2,
  "layers": [
    {"name": "c1", "op": "conv", "inputs": ["input"], "k": 1, "stride": 1,
     "relu": true, "in_shape": [2,2,1], "out_shape": [2,2,1], "in_ch": 1,
     "out_ch": 1},
    {"name": "gap", "op": "gap", "inputs": ["c1"], "in_shape": [2,2,1],
     "out_shape": [1]},
    {"name": "f1", "op": "fc", "inputs": ["gap"], "relu": false,
     "in_shape": [1], "out_shape": [2], "in_ch": 1, "out_ch": 2}
  ],
  "prunable": ["c1", "f1"],
  "dep_groups": [],
  "act_scales": [0.3533568904593639, 0.3533568904593639],
  "act_signed": [false, false],
  "acc_int8": 1.0, "n_params": 5
}"#;

fn mk_env_with(energy: EnergyModel, seed: u64) -> CompressionEnv {
    let arch = ModelArch::from_json(&json::parse(FIX1).unwrap()).unwrap();
    let weights = Weights {
        w: vec![
            Tensor::new(vec![1, 1, 1, 1], vec![2.0]),
            Tensor::new(vec![1, 2], vec![1.0, -1.0]),
        ],
        b: vec![
            Tensor::new(vec![1], vec![-0.4]),
            Tensor::new(vec![2], vec![0.0, 0.25]),
        ],
        sal: vec![Tensor::full(vec![1, 1, 1, 1], 1.0), Tensor::full(vec![1, 2], 1.0)],
        chsq: vec![vec![1.0], vec![1.0, 1.0]],
    };
    let images = Tensor::new(
        vec![4, 2, 2, 1],
        vec![
            0.2, 0.4, 0.6, 0.8, //
            0.05, 0.1, 0.15, 0.1, //
            0.7, 0.7, 0.2, 0.3, //
            0.9, 0.8, 0.7, 0.6,
        ],
    );
    let labels = vec![0i64, 1, 0, 0];
    let data = EvalData::from_arrays(&arch, &images, &labels, 16, arch.batch).unwrap();
    let session =
        InferenceSession::from_backend(Box::new(NativeBackend::new(&arch, data).unwrap()));
    CompressionEnv::new(arch, weights, energy, session, seed).unwrap()
}

fn fixture_dims() -> Vec<LayerDims> {
    ModelArch::from_json(&json::parse(FIX1).unwrap())
        .unwrap()
        .layer_dims()
        .unwrap()
}

#[test]
fn env_steps_on_eyeriss64_match_golden_cost_math() {
    let rq = RqTable::compute(300, 3);
    let golden = GoldenModel::new(fixture_dims(), rq.clone());
    let target = HwTarget::builtin("eyeriss-64").unwrap();
    let em = EnergyModel::for_target(fixture_dims(), &target, rq);
    let mut env = mk_env_with(em, 7);
    let n = env.n_layers();
    env.reset();
    let mut cfgs = vec![Compression::dense(); n];
    for t in 0..n {
        let step = env
            .step(Action { ratio: 0.4, bits: 0.6, alg: t % 7 })
            .unwrap();
        cfgs[t] = Compression {
            sparsity: step.applied.sparsity,
            coarse: step.applied.alg.coarse(),
            bits: step.applied.bits,
        };
        assert_eq!(
            step.energy_gain.to_bits(),
            golden.gain(&cfgs).to_bits(),
            "step {t}: energy gain diverged from the pre-refactor math"
        );
        assert_eq!(
            step.latency_gain.to_bits(),
            golden.latency_gain(&cfgs).to_bits(),
            "step {t}: latency gain diverged from the pre-refactor math"
        );
    }
    // the cost-query phase timer accumulated through the cache
    assert!(env.timers.hw_s >= 0.0);
    assert_eq!(env.timers.steps, n as u64);
}

#[test]
fn search_on_eyeriss64_bit_identical_to_default_accel_ctor() {
    let rq = RqTable::compute(300, 3);
    let cfg = baselines::asqj::AsqjConfig { iters: 6, rho: 0.15, seed: 0 };

    // historical construction: bare Accel::default()
    let mut env_a = mk_env_with(
        EnergyModel::new(fixture_dims(), Accel::default(), rq.clone()),
        7,
    );
    let mut sa = baselines::asqj::AsqjStrategy::new(&cfg, env_a.n_layers());
    let out_a = SearchDriver::plain().run(&mut env_a, &mut sa).unwrap();

    // refactored construction: the named eyeriss-64 target
    let target = HwTarget::builtin("eyeriss-64").unwrap();
    let mut env_b = mk_env_with(
        EnergyModel::for_target(fixture_dims(), &target, rq),
        7,
    );
    let mut sb = baselines::asqj::AsqjStrategy::new(&cfg, env_b.n_layers());
    let out_b = SearchDriver::plain().run(&mut env_b, &mut sb).unwrap();

    assert_eq!(out_a.evals, out_b.evals);
    let (a, b) = (out_a.best.unwrap(), out_b.best.unwrap());
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    assert_eq!(a.acc_loss.to_bits(), b.acc_loss.to_bits());
    assert_eq!(a.energy_gain.to_bits(), b.energy_gain.to_bits());
    assert_eq!(a.latency_gain.to_bits(), b.latency_gain.to_bits());
    assert_eq!(a.reward.to_bits(), b.reward.to_bits());
    for (x, y) in a.per_layer.iter().zip(&b.per_layer) {
        assert_eq!(x.sparsity.to_bits(), y.sparsity.to_bits());
        assert_eq!(x.bits, y.bits);
        assert_eq!(x.alg.index(), y.alg.index());
    }
}

#[test]
fn other_targets_change_the_search_surface() {
    // selecting a different target must actually change the reward
    // surface the search sees (hardware-awareness is not a no-op)
    let rq = RqTable::compute(300, 3);
    let e64 = HwTarget::builtin("eyeriss-64").unwrap();
    let mcu = HwTarget::builtin("mcu").unwrap();
    let mut env_a = mk_env_with(
        EnergyModel::for_target(fixture_dims(), &e64, rq.clone()),
        7,
    );
    let mut env_b = mk_env_with(EnergyModel::for_target(fixture_dims(), &mcu, rq), 7);
    let n = env_a.n_layers();
    let actions: Vec<Action> = (0..n)
        .map(|t| Action { ratio: 0.5, bits: 0.3, alg: t % 7 })
        .collect();
    let sol_a = env_a.evaluate_config(&actions).unwrap();
    let sol_b = env_b.evaluate_config(&actions).unwrap();
    assert_ne!(
        sol_a.energy_gain.to_bits(),
        sol_b.energy_gain.to_bits(),
        "mcu and eyeriss-64 priced the same config identically"
    );
}

// ---------------------------------------------------------------------------
// 3. Profile pinning

#[test]
fn eyeriss64_profile_carries_the_prerefactor_accel_numbers() {
    let t = HwTarget::builtin("eyeriss-64").unwrap();
    let a = &t.accel;
    let d = Accel::default();
    assert_eq!(a.pe_rows, 64);
    assert_eq!(a.pe_cols, 64);
    assert_eq!(a.rf_bytes, 64);
    assert_eq!(a.gb_bytes, 32 * 1024);
    assert_eq!(a.mac_bits, 8);
    assert_eq!(a.e_mac.to_bits(), 1.0f64.to_bits());
    assert_eq!(a.e_rf.to_bits(), 1.0f64.to_bits());
    assert_eq!(a.e_gb.to_bits(), 6.0f64.to_bits());
    assert_eq!(a.e_dram.to_bits(), 200.0f64.to_bits());
    assert_eq!(t.scaling, ComputeScaling::MacSim);
    // and those ARE the Default numbers the old code hardcoded
    assert_eq!(a.pe_rows, d.pe_rows);
    assert_eq!(a.gb_bytes, d.gb_bytes);
    assert_eq!(a.e_dram.to_bits(), d.e_dram.to_bits());
    // every built-in resolves end to end through the CLI path
    for name in BUILTIN_TARGETS {
        let t = HwTarget::resolve(name, None).unwrap();
        assert_eq!(&t.name, name);
    }
}
