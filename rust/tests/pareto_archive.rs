//! Determinism properties of the cross-target Pareto archive.
//!
//! The archive promises a front that is a pure function of the *set*
//! of runs fed into it — never of the order they arrived in (sweep
//! fan-out vs sequential replays, leader re-folds, interleaved hw
//! targets). These tests drive randomly generated entry populations
//! through shuffled insertion orders and assert:
//!
//! 1. **Order-independence**: every permutation of the same entry set
//!    serialises to byte-identical archive JSON.
//! 2. **NSGA-II agreement**: the surviving set is exactly the rank-0
//!    front `baselines::nsga2::nondominated_sort` computes over all
//!    entries ever offered, per (model, fingerprint, hw) group.
//! 3. **Fan-out parity**: folding per-job sub-archives into a leader
//!    file yields the same bytes as one sequential pass, on disk.
//! 4. **Non-finite rejection**: `record_report` refuses NaN/inf
//!    objectives instead of corrupting the file.

use std::path::PathBuf;

use hapq::baselines::nsga2::nondominated_sort;
use hapq::io::json;
use hapq::search::archive::{
    agrees_with_nondominated_sort, record_report, ArchiveEntry, InsertOutcome, ParetoArchive,
    PerLayerPolicy,
};
use hapq::util::rng::Rng;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hapq-pareto-{name}-{}", std::process::id()))
}

/// Random but seed-deterministic entry. Objectives are drawn from a
/// tiny grid so dominance, ties and exact duplicates all actually
/// occur in a 40-entry population.
fn gen_entry(rng: &mut Rng, i: usize) -> ArchiveEntry {
    let models = ["vgg11", "resnet20"];
    let hws = ["eyeriss-64", "mcu", "fpga-dsp"];
    let methods = ["ours", "amc", "haq", "nsga2"];
    let model = models[rng.below(models.len())];
    let grid = |r: &mut Rng| (r.below(5) as f64) * 0.05;
    ArchiveEntry {
        model: model.to_string(),
        // two fingerprints per model name: dominance must scope to the
        // fingerprint, not the human-readable name
        fingerprint: format!("{:016x}", 0xaa00 + rng.below(2) as u64),
        hw: hws[rng.below(hws.len())].to_string(),
        method: methods[rng.below(methods.len())].to_string(),
        seed: i as u64,
        test_acc: 0.9,
        acc_loss: grid(rng),
        val_acc_loss: grid(rng),
        energy_gain: grid(rng),
        latency_gain: grid(rng),
        reward: rng.range(-1.0, 1.0),
        per_layer: vec![PerLayerPolicy {
            alg: "l2-norm".to_string(),
            sparsity: 0.5,
            bits: 4 + rng.below(5) as u32,
        }],
    }
}

fn population(seed: u64, n: usize) -> Vec<ArchiveEntry> {
    let mut rng = Rng::new(seed);
    (0..n).map(|i| gen_entry(&mut rng, i)).collect()
}

fn fold(entries: &[ArchiveEntry]) -> ParetoArchive {
    let mut a = ParetoArchive::new();
    for e in entries {
        a.insert(e.clone()).expect("finite entries insert cleanly");
    }
    a
}

#[test]
fn front_bytes_are_insertion_order_independent() {
    for seed in [1u64, 7, 42] {
        let base = population(seed, 40);
        let reference = fold(&base).to_json().to_string();
        let mut shuffler = Rng::new(seed ^ 0xdead_beef);
        for _ in 0..8 {
            let mut perm = base.clone();
            shuffler.shuffle(&mut perm);
            let got = fold(&perm).to_json().to_string();
            assert_eq!(
                got, reference,
                "permuted insertion order changed the serialised front (seed {seed})"
            );
        }
    }
}

#[test]
fn archive_front_matches_nondominated_sort_rank0() {
    let base = population(3, 60);
    let a = fold(&base);
    // the archive's own invariant check: no survivor is dominated
    // within its group, per the shared NSGA-II machinery
    assert!(agrees_with_nondominated_sort(&a));

    // stronger: the survivors are exactly the rank-0 front of ALL
    // entries ever offered (deduplicated), group by group
    let mut groups: Vec<(String, String, String)> = base
        .iter()
        .map(|e| (e.model.clone(), e.fingerprint.clone(), e.hw.clone()))
        .collect();
    groups.sort();
    groups.dedup();
    for (m, fp, hw) in groups {
        let mut offered: Vec<&ArchiveEntry> = base
            .iter()
            .filter(|e| e.model == m && e.fingerprint == fp && e.hw == hw)
            .collect();
        // exact duplicates collapse to one archived entry
        let mut uniq: Vec<&ArchiveEntry> = Vec::new();
        offered.retain(|e| {
            if uniq.iter().any(|u| u == e) {
                false
            } else {
                uniq.push(*e);
                true
            }
        });
        let objs: Vec<Vec<f64>> = offered.iter().map(|e| e.objectives()).collect();
        let fronts = nondominated_sort(&objs);
        let mut expect: Vec<&ArchiveEntry> = offered
            .iter()
            .enumerate()
            .filter(|(i, _)| fronts[*i] == 0)
            .map(|(_, e)| *e)
            .collect();
        let mut got: Vec<&ArchiveEntry> = a
            .entries()
            .iter()
            .filter(|e| e.model == m && e.fingerprint == fp && e.hw == hw)
            .collect();
        let key = |e: &ArchiveEntry| (e.method.clone(), e.seed);
        expect.sort_by_key(|e| key(e));
        got.sort_by_key(|e| key(e));
        assert_eq!(
            got, expect,
            "archived group ({m}, {fp}, {hw}) is not the nondominated_sort rank-0 front"
        );
    }
}

#[test]
fn fanout_fold_and_sequential_pass_write_identical_files() {
    let base = population(11, 30);
    let dir = tmp("fanout");
    let _ = std::fs::remove_dir_all(&dir);

    // sequential: one pass over the reports in job order
    let seq = dir.join("seq").join("pareto.json");
    for e in &base {
        record_report(&seq, &entry_as_report(e)).unwrap();
    }

    // fan-out: three "jobs" each fold their own shard (reversed, so
    // within-shard order also differs), then a leader folds the shard
    // archives' entries into one file — the launcher's post-sweep fold
    let fan = dir.join("fan").join("pareto.json");
    let mut shards: Vec<Vec<ArchiveEntry>> = vec![Vec::new(); 3];
    for (i, e) in base.iter().enumerate() {
        shards[i % 3].push(e.clone());
    }
    let mut leader = ParetoArchive::load(&fan).unwrap();
    for shard in shards.iter().rev() {
        let mut worker = ParetoArchive::new();
        for e in shard.iter().rev() {
            worker.insert(e.clone()).unwrap();
        }
        for e in worker.entries() {
            leader.insert(e.clone()).unwrap();
        }
    }
    leader.save(&fan).unwrap();

    let seq_bytes = std::fs::read(&seq).unwrap();
    let fan_bytes = std::fs::read(&fan).unwrap();
    assert!(!seq_bytes.is_empty());
    assert_eq!(
        seq_bytes, fan_bytes,
        "fan-out fold and sequential pass disagree on archive bytes"
    );

    // idempotence: re-folding every report leaves the bytes untouched
    for e in &base {
        let out = record_report(&seq, &entry_as_report(e)).unwrap();
        assert!(
            matches!(out, InsertOutcome::Duplicate | InsertOutcome::Dominated),
            "re-fold must never re-insert"
        );
    }
    assert_eq!(std::fs::read(&seq).unwrap(), seq_bytes);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn record_report_rejects_non_finite_objectives() {
    let dir = tmp("nonfinite");
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("pareto.json");

    let mut bad = population(5, 1).remove(0);
    bad.energy_gain = f64::NAN;
    let err = record_report(&path, &entry_as_report(&bad)).unwrap_err();
    assert!(
        err.to_string().contains("non-finite"),
        "error should name the non-finite objective, got: {err}"
    );
    assert!(!path.exists(), "a rejected report must not create the file");

    bad.energy_gain = f64::INFINITY;
    assert!(record_report(&path, &entry_as_report(&bad)).is_err());

    let _ = std::fs::remove_dir_all(&dir);
}

/// Shape an entry as a run-report JSON document (`acc_loss` is named
/// `test_acc_loss` there) so `record_report` can ingest it like a real
/// finished run. Built from constructors, not text — `json::parse`
/// cannot represent the NaN/inf values the rejection test needs.
fn entry_as_report(e: &ArchiveEntry) -> json::Value {
    let layers: Vec<json::Value> = e
        .per_layer
        .iter()
        .map(|l| {
            json::obj(vec![
                ("alg", json::s(&l.alg)),
                ("sparsity", json::num(l.sparsity)),
                ("bits", json::num(l.bits as f64)),
            ])
        })
        .collect();
    json::obj(vec![
        ("model", json::s(&e.model)),
        ("fingerprint", json::s(&e.fingerprint)),
        ("hw", json::s(&e.hw)),
        ("method", json::s(&e.method)),
        ("seed", json::num(e.seed as f64)),
        ("test_acc", json::num(e.test_acc)),
        ("test_acc_loss", json::num(e.acc_loss)),
        ("val_acc_loss", json::num(e.val_acc_loss)),
        ("energy_gain", json::num(e.energy_gain)),
        ("latency_gain", json::num(e.latency_gain)),
        ("reward", json::num(e.reward)),
        ("per_layer", json::arr(layers)),
    ])
}
