//! SearchDriver parity + resume guarantees, with NO artifacts needed:
//! everything runs on the in-memory fixture model of
//! `tests/native_backend.rs`, so these are CI-proof.
//!
//! 1. **Golden parity**: for every method (ours + 5 baselines) this
//!    file carries a verbatim copy of the PRE-REFACTOR hand-rolled
//!    loop (the golden reference) and asserts that the unified
//!    `SearchDriver` + `SearchStrategy` path produces **bit-identical**
//!    best solutions, rewards, curves and eval counts at a fixed seed.
//! 2. **Kill-and-resume**: a run suspended via `stop_after` and
//!    resumed from its checkpoint must reproduce the uninterrupted
//!    run's outcome bit-for-bit (same best, curve, evals).
//! 3. Checkpoint hygiene: atomic writes, header validation, tidy-up on
//!    completion.

use hapq::baselines::{self, better};
use hapq::env::{Action, CompressionEnv, Solution};
use hapq::hw::energy::EnergyModel;
use hapq::hw::mac_sim::RqTable;
use hapq::hw::Accel;
use hapq::io::json;
use hapq::model::{ModelArch, Weights};
use hapq::pruning::PruneAlg;
use hapq::rl::composite::{CompositeAgent, CompositeConfig, CompositeStrategy};
use hapq::rl::ddpg::{Ddpg, DdpgConfig};
use hapq::rl::rainbow::RainbowConfig;
use hapq::rl::replay::Transition;
use hapq::runtime::{EvalData, InferenceSession, NativeBackend};
use hapq::search::{DriverConfig, SearchDriver, SearchStrategy};
use hapq::tensor::Tensor;
use hapq::util::rng::Rng;

// ---------------------------------------------------------------------------
// Synthetic environment (same fixture family as tests/native_backend.rs)

const FIX1: &str = r#"{
  "name": "fix1", "dataset": "synth-fix", "input": [2, 2, 1], "classes": 2,
  "batch": 2,
  "layers": [
    {"name": "c1", "op": "conv", "inputs": ["input"], "k": 1, "stride": 1,
     "relu": true, "in_shape": [2,2,1], "out_shape": [2,2,1], "in_ch": 1,
     "out_ch": 1},
    {"name": "gap", "op": "gap", "inputs": ["c1"], "in_shape": [2,2,1],
     "out_shape": [1]},
    {"name": "f1", "op": "fc", "inputs": ["gap"], "relu": false,
     "in_shape": [1], "out_shape": [2], "in_ch": 1, "out_ch": 2}
  ],
  "prunable": ["c1", "f1"],
  "dep_groups": [],
  "act_scales": [0.3533568904593639, 0.3533568904593639],
  "act_signed": [false, false],
  "acc_int8": 1.0, "n_params": 5
}"#;

fn mk_env(seed: u64) -> CompressionEnv {
    let arch = ModelArch::from_json(&json::parse(FIX1).unwrap()).unwrap();
    let weights = Weights {
        w: vec![
            Tensor::new(vec![1, 1, 1, 1], vec![2.0]),
            Tensor::new(vec![1, 2], vec![1.0, -1.0]),
        ],
        b: vec![
            Tensor::new(vec![1], vec![-0.4]),
            Tensor::new(vec![2], vec![0.0, 0.25]),
        ],
        sal: vec![Tensor::full(vec![1, 1, 1, 1], 1.0), Tensor::full(vec![1, 2], 1.0)],
        chsq: vec![vec![1.0], vec![1.0, 1.0]],
    };
    let images = Tensor::new(
        vec![4, 2, 2, 1],
        vec![
            0.2, 0.4, 0.6, 0.8, //
            0.05, 0.1, 0.15, 0.1, //
            0.7, 0.7, 0.2, 0.3, //
            0.9, 0.8, 0.7, 0.6,
        ],
    );
    let labels = vec![0i64, 1, 0, 0];
    let data = EvalData::from_arrays(&arch, &images, &labels, 16, arch.batch).unwrap();
    let session =
        InferenceSession::from_backend(Box::new(NativeBackend::new(&arch, data).unwrap()));
    let energy = EnergyModel::new(
        arch.layer_dims().unwrap(),
        Accel::default(),
        RqTable::compute(300, 3),
    );
    CompressionEnv::new(arch, weights, energy, session, seed).unwrap()
}

fn assert_sol_eq(a: &Solution, b: &Solution, what: &str) {
    assert_eq!(a.per_layer.len(), b.per_layer.len(), "{what}: per_layer len");
    for (x, y) in a.per_layer.iter().zip(&b.per_layer) {
        assert_eq!(x.alg.index(), y.alg.index(), "{what}: applied alg");
        assert_eq!(x.sparsity.to_bits(), y.sparsity.to_bits(), "{what}: sparsity");
        assert_eq!(x.bits, y.bits, "{what}: applied bits");
        assert_eq!(x.overridden, y.overridden, "{what}: overridden");
    }
    assert_eq!(a.actions.len(), b.actions.len(), "{what}: actions len");
    for (x, y) in a.actions.iter().zip(&b.actions) {
        assert_eq!(x.ratio.to_bits(), y.ratio.to_bits(), "{what}: action ratio");
        assert_eq!(x.bits.to_bits(), y.bits.to_bits(), "{what}: action bits");
        assert_eq!(x.alg, y.alg, "{what}: action alg");
    }
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{what}: accuracy");
    assert_eq!(a.acc_loss.to_bits(), b.acc_loss.to_bits(), "{what}: acc_loss");
    assert_eq!(a.energy_gain.to_bits(), b.energy_gain.to_bits(), "{what}: energy_gain");
    assert_eq!(a.latency_gain.to_bits(), b.latency_gain.to_bits(), "{what}: latency_gain");
    assert_eq!(a.reward.to_bits(), b.reward.to_bits(), "{what}: reward");
}

// ---------------------------------------------------------------------------
// Golden reference loops — verbatim copies of the pre-refactor,
// hand-rolled per-method loops. These are the fixtures the unified
// driver must match bit-for-bit. Do NOT "simplify" them to call the
// new strategies; their whole value is being the historical code.

fn small_composite_cfg() -> CompositeConfig {
    CompositeConfig {
        ddpg: DdpgConfig { hidden: 24, batch: 8, replay_cap: 64, ..DdpgConfig::default() },
        rainbow: RainbowConfig {
            hidden: 12,
            atoms: 11,
            batch: 8,
            replay_cap: 64,
            n_step: 2,
            ..RainbowConfig::default()
        },
        warmup_episodes: 2,
        monitor_window: 4,
        unlock_margin: 0.0,
        max_frozen_episodes: 4,
    }
}

/// Pre-refactor `Coordinator::compress_with` interior (Variant::Full).
fn golden_ours(
    env: &mut CompressionEnv,
    cfg: CompositeConfig,
    seed: u64,
    episodes: usize,
) -> (Solution, Vec<f64>) {
    let mut agent = CompositeAgent::new(cfg, seed);
    let mut best: Option<Solution> = None;
    let mut curve = Vec::with_capacity(episodes);
    for _ep in 0..episodes {
        let mut state = env.reset();
        let mut total = 0.0;
        #[allow(unused_assignments)]
        let mut last = None;
        loop {
            let action = agent.act(&state);
            let step = env.step(action).unwrap();
            agent.observe_and_update(&state, &action, step.reward, &step.state, step.done);
            total += step.reward;
            state = step.state.clone();
            let done = step.done;
            last = Some(step);
            if done {
                break;
            }
        }
        agent.end_episode(total, episodes);
        curve.push(total);
        let sol = env.solution(last.as_ref().unwrap());
        best = better(best, sol);
    }
    // final greedy rollout with the learned policy
    let mut state = env.reset();
    #[allow(unused_assignments)]
    let mut last = None;
    loop {
        let action = agent.act_greedy(&state);
        let step = env.step(action).unwrap();
        state = step.state.clone();
        let done = step.done;
        last = Some(step);
        if done {
            break;
        }
    }
    let greedy = env.solution(last.as_ref().unwrap());
    best = better(best, greedy);
    (best.unwrap(), curve)
}

/// Pre-refactor `baselines::amc::run`.
fn golden_amc(env: &mut CompressionEnv, episodes: usize, warmup: usize, seed: u64) -> Solution {
    let mut agent = Ddpg::new(
        DdpgConfig { action_dim: 1, ..DdpgConfig::default() },
        seed ^ 0xA3C,
    );
    let mut rng = Rng::new(seed ^ 0x11);
    let mut best: Option<Solution> = None;
    for ep in 0..episodes {
        let mut s = env.reset();
        #[allow(unused_assignments)]
        let mut last = None;
        loop {
            let a = if ep < warmup {
                vec![rng.uniform() as f32]
            } else {
                agent.act(&s, true)
            };
            let action = Action {
                ratio: a[0] as f64,
                bits: 1.0,
                alg: PruneAlg::L1Ranked.index(),
            };
            let step = env.step(action).unwrap();
            agent.observe(Transition {
                s: s.clone(),
                a: a.clone(),
                alg: action.alg,
                r: step.reward as f32,
                s2: step.state.clone(),
                done: step.done,
            });
            agent.update();
            s = step.state.clone();
            let done = step.done;
            last = Some(step);
            if done {
                break;
            }
        }
        if ep >= warmup {
            agent.decay_noise();
        }
        let sol = env.solution(last.as_ref().unwrap());
        best = better(best, sol);
    }
    best.unwrap()
}

/// Pre-refactor `baselines::haq::run`.
fn golden_haq(env: &mut CompressionEnv, episodes: usize, warmup: usize, seed: u64) -> Solution {
    let mut agent = Ddpg::new(
        DdpgConfig { action_dim: 1, ..DdpgConfig::default() },
        seed ^ 0x4A9,
    );
    let mut rng = Rng::new(seed ^ 0x22);
    let mut best: Option<Solution> = None;
    for ep in 0..episodes {
        let mut s = env.reset();
        #[allow(unused_assignments)]
        let mut last = None;
        loop {
            let a = if ep < warmup {
                vec![rng.uniform() as f32]
            } else {
                agent.act(&s, true)
            };
            let action = Action { ratio: 0.0, bits: a[0] as f64, alg: 0 };
            let step = env.step(action).unwrap();
            agent.observe(Transition {
                s: s.clone(),
                a: a.clone(),
                alg: 0,
                r: step.reward as f32,
                s2: step.state.clone(),
                done: step.done,
            });
            agent.update();
            s = step.state.clone();
            let done = step.done;
            last = Some(step);
            if done {
                break;
            }
        }
        if ep >= warmup {
            agent.decay_noise();
        }
        let sol = env.solution(last.as_ref().unwrap());
        best = better(best, sol);
    }
    best.unwrap()
}

fn asqj_config_actions(sparsity: &[f64], bits: &[f64]) -> Vec<Action> {
    sparsity
        .iter()
        .zip(bits)
        .map(|(&s, &b)| Action {
            ratio: (s / hapq::env::MAX_RATIO).clamp(0.0, 1.0),
            bits: b.clamp(0.0, 1.0),
            alg: PruneAlg::Level.index(),
        })
        .collect()
}

/// Pre-refactor `baselines::asqj::run`.
fn golden_asqj(env: &mut CompressionEnv, iters: usize, rho: f64) -> Solution {
    let n = env.n_layers();
    let mut sparsity = vec![0.3f64; n];
    let mut bits = vec![1.0f64; n];
    let mut dual = vec![0.0f64; n];
    let mut best: Option<Solution> = None;
    let mut prev_reward = f64::NEG_INFINITY;
    for it in 0..iters {
        let sol = env.evaluate_config(&asqj_config_actions(&sparsity, &bits)).unwrap();
        let improved = sol.reward > prev_reward;
        prev_reward = sol.reward;
        for l in 0..n {
            if improved && sol.acc_loss < 0.05 {
                dual[l] += rho * (1.0 - sol.acc_loss * 10.0);
            } else {
                dual[l] -= rho * (0.5 + sparsity[l]);
            }
            dual[l] = dual[l].clamp(-2.0, 2.0);
            sparsity[l] = (0.3 + 0.25 * dual[l]).clamp(0.0, 0.85);
            bits[l] = (1.0 - 0.3 * dual[l].max(0.0) - 0.02 * (it % 5) as f64).clamp(0.0, 1.0);
        }
        best = better(best, sol);
    }
    best.unwrap()
}

fn opq_sparsity_allocation(env: &CompressionEnv, global: f64) -> Vec<f64> {
    let weights = env.dense_weights();
    let mut normed: Vec<Vec<f32>> = Vec::new();
    for t in weights.w.iter() {
        let sigma = (t.l2() / (t.len() as f32).sqrt()).max(1e-8);
        normed.push(t.data.iter().map(|x| x.abs() / sigma).collect());
    }
    let mut pooled: Vec<f32> = normed.iter().flatten().copied().collect();
    pooled.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let k = ((pooled.len() as f64) * global) as usize;
    let lambda = pooled[k.min(pooled.len() - 1)];
    normed
        .iter()
        .map(|layer| {
            let below = layer.iter().filter(|&&x| x < lambda).count();
            (below as f64 / layer.len().max(1) as f64).min(0.88)
        })
        .collect()
}

fn opq_bit_allocation(env: &CompressionEnv, avg_bits: f64) -> Vec<f64> {
    use hapq::env::{MAX_BITS, MIN_BITS};
    let weights = env.dense_weights();
    let vars: Vec<f64> = weights
        .w
        .iter()
        .map(|t| {
            let mm = t.channel_minmax(false);
            let range: f64 = mm
                .iter()
                .filter(|(a, b)| a.is_finite() && b.is_finite())
                .map(|(a, b)| (b - a) as f64)
                .sum::<f64>()
                / mm.len().max(1) as f64;
            (range * range).max(1e-12)
        })
        .collect();
    let log_gm = vars.iter().map(|v| v.ln()).sum::<f64>() / vars.len() as f64;
    vars.iter()
        .map(|v| {
            let b = avg_bits + 0.5 * (v.ln() - log_gm) / std::f64::consts::LN_2;
            b.clamp(MIN_BITS as f64, MAX_BITS as f64)
        })
        .collect()
}

/// Pre-refactor `baselines::opq::run` (default sweep).
fn golden_opq(env: &mut CompressionEnv) -> Solution {
    use hapq::env::{MAX_BITS, MIN_BITS};
    let budgets = [0.2, 0.35, 0.5, 0.65];
    let bit_budgets = [5.0, 6.0, 7.0];
    let mut best: Option<Solution> = None;
    for &budget in &budgets {
        let sp = opq_sparsity_allocation(env, budget);
        for &bb in &bit_budgets {
            let bits = opq_bit_allocation(env, bb);
            let actions: Vec<Action> = sp
                .iter()
                .zip(&bits)
                .map(|(&s, &b)| Action {
                    ratio: (s / hapq::env::MAX_RATIO).clamp(0.0, 1.0),
                    bits: ((b - MIN_BITS as f64) / (MAX_BITS - MIN_BITS) as f64).clamp(0.0, 1.0),
                    alg: PruneAlg::Level.index(),
                })
                .collect();
            let sol = env.evaluate_config(&actions).unwrap();
            best = better(best, sol);
        }
    }
    best.unwrap()
}

// -- NSGA-II golden reference (private operators copied verbatim) ----------

#[derive(Clone)]
struct GoldenIndividual {
    genes: Vec<f64>,
    obj: Vec<f64>,
    sol: Option<Solution>,
}

fn nsga2_decode(genes: &[f64]) -> Vec<Action> {
    genes
        .chunks(3)
        .map(|g| Action { ratio: g[0], bits: g[1], alg: (g[2] * 6.999) as usize })
        .collect()
}

fn nsga2_sbx(a: &[f64], b: &[f64], eta: f64, rng: &mut Rng) -> (Vec<f64>, Vec<f64>) {
    let mut c1 = a.to_vec();
    let mut c2 = b.to_vec();
    for i in 0..a.len() {
        if rng.uniform() < 0.5 {
            let u = rng.uniform();
            let beta = if u <= 0.5 {
                (2.0 * u).powf(1.0 / (eta + 1.0))
            } else {
                (1.0 / (2.0 * (1.0 - u))).powf(1.0 / (eta + 1.0))
            };
            c1[i] = (0.5 * ((1.0 + beta) * a[i] + (1.0 - beta) * b[i])).clamp(0.0, 1.0);
            c2[i] = (0.5 * ((1.0 - beta) * a[i] + (1.0 + beta) * b[i])).clamp(0.0, 1.0);
        }
    }
    (c1, c2)
}

fn nsga2_poly_mutate(g: &mut [f64], eta: f64, p: f64, rng: &mut Rng) {
    for x in g.iter_mut() {
        if rng.uniform() < p {
            let u = rng.uniform();
            let delta = if u < 0.5 {
                (2.0 * u).powf(1.0 / (eta + 1.0)) - 1.0
            } else {
                1.0 - (2.0 * (1.0 - u)).powf(1.0 / (eta + 1.0))
            };
            *x = (*x + delta).clamp(0.0, 1.0);
        }
    }
}

/// Pre-refactor `baselines::nsga2::run`.
#[allow(clippy::too_many_arguments)]
fn golden_nsga2(
    env: &mut CompressionEnv,
    pop_size: usize,
    generations: usize,
    eta_c: f64,
    eta_m: f64,
    p_mut: f64,
    seed: u64,
) -> Solution {
    use hapq::baselines::nsga2::{crowding, nondominated_sort};
    let n_genes = 3 * env.n_layers();
    let mut rng = Rng::new(seed ^ 0x6A);
    let evaluate = |env: &mut CompressionEnv, ind: &mut GoldenIndividual| {
        let sol = env.evaluate_config(&nsga2_decode(&ind.genes)).unwrap();
        ind.obj = vec![-sol.reward];
        ind.sol = Some(sol);
    };
    let mut pop: Vec<GoldenIndividual> = (0..pop_size)
        .map(|_| GoldenIndividual {
            genes: (0..n_genes).map(|_| rng.uniform()).collect(),
            obj: vec![],
            sol: None,
        })
        .collect();
    for ind in pop.iter_mut() {
        evaluate(env, ind);
    }
    let mut best: Option<Solution> = None;
    for ind in &pop {
        best = better(best, ind.sol.clone().unwrap());
    }
    for _gen in 0..generations {
        let mut offspring = Vec::with_capacity(pop_size);
        while offspring.len() < pop_size {
            let pick = |rng: &mut Rng, pop: &[GoldenIndividual]| {
                let i = rng.below(pop.len());
                let j = rng.below(pop.len());
                if pop[i].obj[0] <= pop[j].obj[0] { i } else { j }
            };
            let (i, j) = (pick(&mut rng, &pop), pick(&mut rng, &pop));
            let (mut c1, mut c2) = nsga2_sbx(&pop[i].genes, &pop[j].genes, eta_c, &mut rng);
            nsga2_poly_mutate(&mut c1, eta_m, p_mut, &mut rng);
            nsga2_poly_mutate(&mut c2, eta_m, p_mut, &mut rng);
            offspring.push(GoldenIndividual { genes: c1, obj: vec![], sol: None });
            if offspring.len() < pop_size {
                offspring.push(GoldenIndividual { genes: c2, obj: vec![], sol: None });
            }
        }
        for ind in offspring.iter_mut() {
            evaluate(env, ind);
            best = better(best, ind.sol.clone().unwrap());
        }
        let mut combined = pop;
        combined.append(&mut offspring);
        let objs: Vec<Vec<f64>> = combined.iter().map(|i| i.obj.clone()).collect();
        let fronts = nondominated_sort(&objs);
        let mut order: Vec<usize> = (0..combined.len()).collect();
        let max_front = fronts.iter().max().copied().unwrap_or(0);
        let mut crowd = vec![0.0f64; combined.len()];
        for f in 0..=max_front {
            let members: Vec<usize> =
                (0..combined.len()).filter(|&i| fronts[i] == f).collect();
            if members.is_empty() {
                continue;
            }
            let d = crowding(&objs, &members);
            for (mi, &i) in members.iter().enumerate() {
                crowd[i] = d[mi];
            }
        }
        order.sort_by(|&a, &b| {
            fronts[a]
                .cmp(&fronts[b])
                .then(crowd[b].partial_cmp(&crowd[a]).unwrap())
        });
        pop = order[..pop_size].iter().map(|&i| combined[i].clone()).collect();
    }
    best.unwrap()
}

// ---------------------------------------------------------------------------
// Parity: driver == golden reference, bit for bit

const ENV_SEED: u64 = 7;

#[test]
fn driver_matches_golden_ours() {
    let episodes = 10;
    let seed = 42;
    let mut env_ref = mk_env(ENV_SEED);
    let (gold, gold_curve) = golden_ours(&mut env_ref, small_composite_cfg(), seed, episodes);

    let mut env = mk_env(ENV_SEED);
    let agent = CompositeAgent::new(small_composite_cfg(), seed);
    let mut strategy = CompositeStrategy::new(agent, episodes);
    let outcome = SearchDriver::plain().run(&mut env, &mut strategy).unwrap();
    assert!(!outcome.suspended);
    assert_eq!(outcome.episodes_run, episodes);
    assert_eq!(outcome.curve.len(), gold_curve.len());
    for (x, y) in outcome.curve.iter().zip(&gold_curve) {
        assert_eq!(x.to_bits(), y.to_bits(), "reward curve diverged");
    }
    assert_sol_eq(outcome.best.as_ref().unwrap(), &gold, "ours");
    // identical oracle-eval accounting, greedy rollout included
    assert_eq!(outcome.evals, env_ref.n_evals);
}

#[test]
fn driver_matches_golden_amc() {
    // stays under the replay threshold: DDPG updates on the paper-sized
    // 300-wide nets are debug-build slow, and the update path is
    // already parity+resume-covered by the small-net composite tests
    let (episodes, warmup, seed) = (12, 3, 5);
    let mut env_ref = mk_env(ENV_SEED);
    let gold = golden_amc(&mut env_ref, episodes, warmup, seed);

    let mut env = mk_env(ENV_SEED);
    let mut strategy =
        baselines::amc::AmcStrategy::new(&baselines::amc::AmcConfig { episodes, warmup, seed });
    let outcome = SearchDriver::plain().run(&mut env, &mut strategy).unwrap();
    assert_sol_eq(outcome.best.as_ref().unwrap(), &gold, "amc");
    assert_eq!(outcome.evals, env_ref.n_evals);
    assert!(outcome.curve.is_empty(), "baselines record no curve");
}

#[test]
fn driver_matches_golden_haq() {
    let (episodes, warmup, seed) = (8, 2, 9);
    let mut env_ref = mk_env(ENV_SEED);
    let gold = golden_haq(&mut env_ref, episodes, warmup, seed);

    let mut env = mk_env(ENV_SEED);
    let mut strategy =
        baselines::haq::HaqStrategy::new(&baselines::haq::HaqConfig { episodes, warmup, seed });
    let outcome = SearchDriver::plain().run(&mut env, &mut strategy).unwrap();
    assert_sol_eq(outcome.best.as_ref().unwrap(), &gold, "haq");
    assert_eq!(outcome.evals, env_ref.n_evals);
}

#[test]
fn driver_matches_golden_asqj() {
    let (iters, rho) = (8, 0.15);
    let mut env_ref = mk_env(ENV_SEED);
    let gold = golden_asqj(&mut env_ref, iters, rho);

    let mut env = mk_env(ENV_SEED);
    let cfg = baselines::asqj::AsqjConfig { iters, rho, seed: 0 };
    let mut strategy = baselines::asqj::AsqjStrategy::new(&cfg, env.n_layers());
    let outcome = SearchDriver::plain().run(&mut env, &mut strategy).unwrap();
    assert_sol_eq(outcome.best.as_ref().unwrap(), &gold, "asqj");
    assert_eq!(outcome.evals, env_ref.n_evals);
}

#[test]
fn driver_matches_golden_opq() {
    let mut env_ref = mk_env(ENV_SEED);
    let gold = golden_opq(&mut env_ref);

    let mut env = mk_env(ENV_SEED);
    let mut strategy =
        baselines::opq::OpqStrategy::new(&env, &baselines::opq::OpqConfig::default());
    let outcome = SearchDriver::plain().run(&mut env, &mut strategy).unwrap();
    assert_eq!(strategy.episodes(), 12, "default sweep is 4 budgets x 3 bit budgets");
    assert_sol_eq(outcome.best.as_ref().unwrap(), &gold, "opq");
    assert_eq!(outcome.evals, env_ref.n_evals);
}

#[test]
fn driver_matches_golden_nsga2() {
    let (pop, generations, seed) = (4, 3, 11);
    let (eta_c, eta_m, p_mut) = (15.0, 20.0, 0.3);
    let mut env_ref = mk_env(ENV_SEED);
    let gold = golden_nsga2(&mut env_ref, pop, generations, eta_c, eta_m, p_mut, seed);

    let mut env = mk_env(ENV_SEED);
    let cfg = baselines::nsga2::Nsga2Config { pop, generations, eta_c, eta_m, p_mut, seed };
    let mut strategy = baselines::nsga2::Nsga2Strategy::new(&cfg, env.n_layers());
    let outcome = SearchDriver::plain().run(&mut env, &mut strategy).unwrap();
    assert_eq!(outcome.episodes_run, pop + generations * pop);
    assert_sol_eq(outcome.best.as_ref().unwrap(), &gold, "nsga2");
    assert_eq!(outcome.evals, env_ref.n_evals);
}

// ---------------------------------------------------------------------------
// Kill-and-resume: suspended + resumed == uninterrupted, bit for bit

fn ckpt_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("hapq-resume-{name}-{}.ckpt", std::process::id()))
}

fn run_resume_case(
    name: &str,
    mk_strategy: &dyn Fn(&CompressionEnv) -> Box<dyn SearchStrategy>,
    stop_after: usize,
    driver_seed: u64,
) {
    // A: uninterrupted
    let mut env_a = mk_env(ENV_SEED);
    let mut sa = mk_strategy(&env_a);
    let drv = |checkpoint, resume, stop| {
        SearchDriver::new(DriverConfig {
            model: "fix1".into(),
            seed: driver_seed,
            checkpoint,
            checkpoint_every: 0, // suspension is the only write
            resume,
            stop_after: stop,
            ..Default::default()
        })
    };
    let out_a = drv(None, false, None).run(&mut env_a, sa.as_mut()).unwrap();

    // B: run `stop_after` episodes, suspend into the checkpoint
    let ckpt = ckpt_path(name);
    let _ = std::fs::remove_file(&ckpt);
    let mut env_b = mk_env(ENV_SEED);
    let mut sb = mk_strategy(&env_b);
    let out_b = drv(Some(ckpt.clone()), false, Some(stop_after))
        .run(&mut env_b, sb.as_mut())
        .unwrap();
    assert!(out_b.suspended, "{name}: expected suspension");
    assert_eq!(out_b.episodes_run, stop_after, "{name}: suspension point");
    assert!(ckpt.exists(), "{name}: checkpoint must exist after suspension");
    // atomic write leaves no temp file behind
    assert!(
        !ckpt.with_file_name(format!(
            "{}.tmp",
            ckpt.file_name().unwrap().to_str().unwrap()
        ))
        .exists(),
        "{name}: stale .tmp after checkpoint write"
    );

    // C: fresh process state (new env + strategy), resumed from the file
    let mut env_c = mk_env(ENV_SEED);
    let mut sc = mk_strategy(&env_c);
    let out_c = drv(Some(ckpt.clone()), true, None)
        .run(&mut env_c, sc.as_mut())
        .unwrap();
    assert!(!out_c.suspended, "{name}: resume must complete");
    assert_eq!(out_a.evals, out_c.evals, "{name}: eval accounting");
    assert_eq!(out_a.episodes_run, out_c.episodes_run, "{name}: episodes");
    assert_eq!(out_a.curve.len(), out_c.curve.len(), "{name}: curve length");
    for (x, y) in out_a.curve.iter().zip(&out_c.curve) {
        assert_eq!(x.to_bits(), y.to_bits(), "{name}: curve diverged across resume");
    }
    assert_sol_eq(
        out_a.best.as_ref().unwrap(),
        out_c.best.as_ref().unwrap(),
        name,
    );
    assert!(!ckpt.exists(), "{name}: completed run must tidy its checkpoint");
}

#[test]
fn resume_reproduces_uninterrupted_ours() {
    run_resume_case(
        "ours",
        &|_env| {
            Box::new(CompositeStrategy::new(
                CompositeAgent::new(small_composite_cfg(), 42),
                10,
            ))
        },
        // suspend mid-training, after Rainbow can be unlocked
        6,
        42,
    );
}

#[test]
fn resume_reproduces_uninterrupted_amc() {
    run_resume_case(
        "amc",
        &|_env| {
            Box::new(baselines::amc::AmcStrategy::new(&baselines::amc::AmcConfig {
                episodes: 12,
                warmup: 3,
                seed: 5,
            }))
        },
        // suspend after the warmup/policy boundary so both exploration
        // modes cross the checkpoint
        5,
        5,
    );
}

#[test]
fn resume_reproduces_uninterrupted_asqj() {
    run_resume_case(
        "asqj",
        &|env| {
            Box::new(baselines::asqj::AsqjStrategy::new(
                &baselines::asqj::AsqjConfig { iters: 8, rho: 0.15, seed: 0 },
                env.n_layers(),
            ))
        },
        3,
        0,
    );
}

#[test]
fn resume_reproduces_uninterrupted_opq() {
    run_resume_case(
        "opq",
        &|env| {
            Box::new(baselines::opq::OpqStrategy::new(
                env,
                &baselines::opq::OpqConfig::default(),
            ))
        },
        5,
        0,
    );
}

#[test]
fn resume_reproduces_uninterrupted_nsga2() {
    run_resume_case(
        "nsga2",
        &|env| {
            Box::new(baselines::nsga2::Nsga2Strategy::new(
                &baselines::nsga2::Nsga2Config {
                    pop: 4,
                    generations: 3,
                    p_mut: 0.3,
                    seed: 11,
                    ..Default::default()
                },
                env.n_layers(),
            ))
        },
        // suspend mid-offspring-batch: queue state must round-trip
        6,
        11,
    );
}

// ---------------------------------------------------------------------------
// Batched candidate pricing: env purity + serial ground truth + driver
// hook wiring

#[test]
fn price_candidates_matches_serial_steps_and_keeps_episode_pure() {
    let probe_cands: Vec<Action> = (0..5)
        .map(|i| Action { ratio: 0.1 + 0.15 * i as f64, bits: 0.2 + 0.15 * i as f64, alg: i % 7 })
        .collect();
    let ep_actions = [
        Action { ratio: 0.3, bits: 0.7, alg: 1 },
        Action { ratio: 0.5, bits: 0.4, alg: 4 },
    ];

    // twin A: the plain episode
    let mut env_a = mk_env(ENV_SEED);
    env_a.reset();
    let steps_a: Vec<_> =
        (0..env_a.n_layers()).map(|t| env_a.step(ep_actions[t]).unwrap()).collect();

    // twin B: same episode, but a pricing batch fires before every step
    let mut env_b = mk_env(ENV_SEED);
    env_b.reset();
    let mut prices = Vec::new();
    for (t, st_a) in steps_a.iter().enumerate() {
        prices.push(env_b.price_candidates(&probe_cands).unwrap());
        let st_b = env_b.step(ep_actions[t]).unwrap();
        // bitwise: pricing must not perturb the episode stream
        assert_eq!(st_b.reward.to_bits(), st_a.reward.to_bits(), "reward diverged at t={t}");
        assert_eq!(st_b.done, st_a.done, "done flag diverged at t={t}");
        for (x, y) in st_b.state.iter().zip(&st_a.state) {
            assert_eq!(x.to_bits(), y.to_bits(), "state diverged at t={t}");
        }
    }
    // eval accounting: the episode's evals plus K per pricing call
    assert_eq!(
        env_b.n_evals,
        env_a.n_evals + (probe_cands.len() * steps_a.len()) as u64,
        "price_candidates must count its oracle queries"
    );

    // serial ground truth: each price equals the reward a twin env gets
    // from actually step()ing that candidate at the same point
    for (t, price_row) in prices.iter().enumerate() {
        assert_eq!(price_row.len(), probe_cands.len());
        for (ci, cand) in probe_cands.iter().enumerate() {
            let mut env_c = mk_env(ENV_SEED);
            env_c.reset();
            for a in &ep_actions[..t] {
                env_c.step(*a).unwrap();
            }
            let st = env_c.step(*cand).unwrap();
            assert_eq!(
                price_row[ci].to_bits(),
                st.reward.to_bits(),
                "price != serial step reward at t={t}, candidate {ci}"
            );
        }
    }
}

/// A fixed-sequence strategy that (optionally) prices a candidate
/// batch before every step and records what the env hands back.
struct ProbingStrategy {
    actions: Vec<Action>,
    cands: Vec<Action>,
    seen: Vec<(usize, Vec<f64>)>,
    probe: bool,
}

impl SearchStrategy for ProbingStrategy {
    fn method(&self) -> &str {
        "probe"
    }
    fn episodes(&self) -> usize {
        1
    }
    fn propose(&mut self, t: usize, _state: &[f32]) -> Action {
        self.actions[t]
    }
    fn propose_candidates(&mut self, _t: usize, _state: &[f32]) -> Option<Vec<Action>> {
        if self.probe {
            Some(self.cands.clone())
        } else {
            None
        }
    }
    fn observe_candidates(&mut self, t: usize, _cands: &[Action], rewards: &[f64]) {
        self.seen.push((t, rewards.to_vec()));
    }
    fn save_state(&self, _w: &mut hapq::io::bin::BinWriter) {}
    fn load_state(&mut self, _r: &mut hapq::io::bin::BinReader) -> anyhow::Result<()> {
        Ok(())
    }
}

#[test]
fn driver_candidate_hooks_price_batches_without_perturbing_the_search() {
    let ep_actions = vec![
        Action { ratio: 0.3, bits: 0.7, alg: 1 },
        Action { ratio: 0.5, bits: 0.4, alg: 4 },
    ];
    let cands: Vec<Action> = (0..3)
        .map(|i| Action { ratio: 0.2 + 0.2 * i as f64, bits: 0.3 + 0.2 * i as f64, alg: i })
        .collect();

    let mut env_plain = mk_env(ENV_SEED);
    let mut s_plain = ProbingStrategy {
        actions: ep_actions.clone(),
        cands: vec![],
        seen: vec![],
        probe: false,
    };
    let out_plain = SearchDriver::plain().run(&mut env_plain, &mut s_plain).unwrap();
    assert!(s_plain.seen.is_empty(), "no candidates proposed, none observed");

    let mut env_probe = mk_env(ENV_SEED);
    let mut s_probe = ProbingStrategy {
        actions: ep_actions.clone(),
        cands: cands.clone(),
        seen: vec![],
        probe: true,
    };
    let out_probe = SearchDriver::plain().run(&mut env_probe, &mut s_probe).unwrap();

    // pricing fired at every layer, one reward per candidate, in order
    assert_eq!(s_probe.seen.len(), env_probe.n_layers());
    for (t, (seen_t, rewards)) in s_probe.seen.iter().enumerate() {
        assert_eq!(*seen_t, t, "observe_candidates layer order");
        assert_eq!(rewards.len(), cands.len(), "one reward per candidate");
    }
    // ...and left the search outcome bit-identical to the no-hook run
    assert_sol_eq(
        out_plain.best.as_ref().unwrap(),
        out_probe.best.as_ref().unwrap(),
        "candidate hooks",
    );
    assert_eq!(out_plain.episodes_run, out_probe.episodes_run);
    assert_eq!(
        env_probe.n_evals,
        env_plain.n_evals + (cands.len() * env_probe.n_layers()) as u64,
        "hook pricing must be accounted as extra oracle evals"
    );
}

// ---------------------------------------------------------------------------
// Checkpoint hygiene

#[test]
fn resume_with_missing_checkpoint_runs_from_scratch() {
    let ckpt = ckpt_path("fresh");
    let _ = std::fs::remove_file(&ckpt);
    let mut env = mk_env(ENV_SEED);
    let cfg = baselines::asqj::AsqjConfig { iters: 4, ..Default::default() };
    let mut s = baselines::asqj::AsqjStrategy::new(&cfg, env.n_layers());
    let driver = SearchDriver::new(DriverConfig {
        model: "fix1".into(),
        checkpoint: Some(ckpt.clone()),
        resume: true,
        ..Default::default()
    });
    let out = driver.run(&mut env, &mut s).unwrap();
    assert!(!out.suspended);
    assert_eq!(out.episodes_run, 4);

    // and it must match the plain run
    let mut env2 = mk_env(ENV_SEED);
    let mut s2 = baselines::asqj::AsqjStrategy::new(&cfg, env2.n_layers());
    let plain = SearchDriver::plain().run(&mut env2, &mut s2).unwrap();
    assert_sol_eq(out.best.as_ref().unwrap(), plain.best.as_ref().unwrap(), "fresh-resume");
}

#[test]
fn checkpoint_of_different_run_is_rejected() {
    let ckpt = ckpt_path("mismatch");
    let _ = std::fs::remove_file(&ckpt);
    // suspend an asqj run with seed 0
    let cfg = baselines::asqj::AsqjConfig { iters: 6, ..Default::default() };
    let mut env = mk_env(ENV_SEED);
    let mut s = baselines::asqj::AsqjStrategy::new(&cfg, env.n_layers());
    let out = SearchDriver::new(DriverConfig {
        model: "fix1".into(),
        seed: 0,
        checkpoint: Some(ckpt.clone()),
        checkpoint_every: 0,
        stop_after: Some(2),
        ..Default::default()
    })
    .run(&mut env, &mut s)
    .unwrap();
    assert!(out.suspended);

    // a non-resume run must refuse to clobber the suspended state
    let mut env_c = mk_env(ENV_SEED);
    let mut s_c = baselines::asqj::AsqjStrategy::new(&cfg, env_c.n_layers());
    let err = SearchDriver::new(DriverConfig {
        model: "fix1".into(),
        seed: 0,
        checkpoint: Some(ckpt.clone()),
        ..Default::default()
    })
    .run(&mut env_c, &mut s_c);
    assert!(err.is_err(), "existing checkpoint must not be silently overwritten");
    assert!(ckpt.exists(), "refusal must leave the checkpoint intact");

    // a different seed must refuse the file
    let mut env2 = mk_env(ENV_SEED);
    let mut s2 = baselines::asqj::AsqjStrategy::new(&cfg, env2.n_layers());
    let err = SearchDriver::new(DriverConfig {
        model: "fix1".into(),
        seed: 1,
        checkpoint: Some(ckpt.clone()),
        resume: true,
        ..Default::default()
    })
    .run(&mut env2, &mut s2);
    assert!(err.is_err(), "seed-mismatched checkpoint must be rejected");
    // so must a different method
    let mut env3 = mk_env(ENV_SEED);
    let mut s3 = baselines::opq::OpqStrategy::new(&env3, &baselines::opq::OpqConfig::default());
    let err = SearchDriver::new(DriverConfig {
        model: "fix1".into(),
        seed: 0,
        checkpoint: Some(ckpt.clone()),
        resume: true,
        ..Default::default()
    })
    .run(&mut env3, &mut s3);
    assert!(err.is_err(), "method-mismatched checkpoint must be rejected");
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn periodic_checkpoints_are_written_and_resumable() {
    let ckpt = ckpt_path("periodic");
    let _ = std::fs::remove_file(&ckpt);
    let cfg = baselines::asqj::AsqjConfig { iters: 6, ..Default::default() };

    // drive 4 of 6 episodes with checkpoint_every=2, then kill the run
    // by dropping it — simulate by running a stop_after at 4 with
    // periodic writes enabled (the ep-2 checkpoint is overwritten by
    // the ep-4 suspension write; both paths share the same format)
    let mut env = mk_env(ENV_SEED);
    let mut s = baselines::asqj::AsqjStrategy::new(&cfg, env.n_layers());
    let out = SearchDriver::new(DriverConfig {
        model: "fix1".into(),
        checkpoint: Some(ckpt.clone()),
        checkpoint_every: 2,
        stop_after: Some(4),
        ..Default::default()
    })
    .run(&mut env, &mut s)
    .unwrap();
    assert!(out.suspended);
    assert!(ckpt.exists());

    let mut env2 = mk_env(ENV_SEED);
    let mut s2 = baselines::asqj::AsqjStrategy::new(&cfg, env2.n_layers());
    let resumed = SearchDriver::new(DriverConfig {
        model: "fix1".into(),
        checkpoint: Some(ckpt.clone()),
        resume: true,
        ..Default::default()
    })
    .run(&mut env2, &mut s2)
    .unwrap();

    let mut env3 = mk_env(ENV_SEED);
    let mut s3 = baselines::asqj::AsqjStrategy::new(&cfg, env3.n_layers());
    let plain = SearchDriver::plain().run(&mut env3, &mut s3).unwrap();
    assert_sol_eq(
        resumed.best.as_ref().unwrap(),
        plain.best.as_ref().unwrap(),
        "periodic",
    );
}
