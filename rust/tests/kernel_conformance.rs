//! Kernel-conformance suite: the integer fast path (`--kernel int`)
//! must produce logits **bit-identical** to the f32 reference forward
//! at every bit-width, prune ratio, thread count, and after arbitrary
//! `invalidate()` sequences on the incremental engine.
//!
//! Fixtures are random branched mini-graphs (residual add, optional
//! channel concat, optional depthwise branch) whose weights go through
//! the real compression pipeline — `pruning::prune` (fine + coarse
//! algorithms, so the packed planes see scattered zeros AND dead
//! channels) followed by `quant::quantize_weights` — exactly the
//! tensors the reward oracle scores during search. Activation
//! precisions sweep the paper's range {2, 3, 4, 6, 8}.
//!
//! Equality is asserted with `==` on the logits vectors: the int
//! kernel is bit-exact by construction (see `nn/mat.rs`), not within a
//! tolerance.

use std::collections::HashMap;
use std::sync::Arc;

use hapq::model::{Layer, ModelArch, Op, Weights};
use hapq::nn::mat::{set_gemm_tile, CodeMat, Mat, PackedMat, DEFAULT_GEMM_TILE};
use hapq::pruning::{prune, PruneAlg, PruneCtx};
use hapq::quant::{quantize_weights, QuantGrid};
use hapq::runtime::native::quant_params;
use hapq::runtime::{
    Candidate, EvalData, InferenceBackend, KernelKind, MemoConfig, NativeBackend, SchedKind,
};
use hapq::tensor::Tensor;
use hapq::util::proptest::forall;
use hapq::util::rng::Rng;

/// Activation precisions the conformance sweep draws from (paper §4.1).
const BITS: [f32; 5] = [2.0, 3.0, 4.0, 6.0, 8.0];

/// One randomly generated, pruned + weight-quantized mini-model.
struct Fixture {
    seed: u64,
    arch: ModelArch,
    weights: Weights,
    act_bits: Vec<f32>,
    images: Tensor,
    labels: Vec<i64>,
}

impl std::fmt::Debug for Fixture {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Fixture {{ seed: {:#x}, layers: {:?}, act_bits: {:?}, sparsity: {:.2} }}",
            self.seed,
            self.arch.layers.iter().map(|l| (&l.name, l.op)).collect::<Vec<_>>(),
            self.act_bits,
            self.weights.sparsity(),
        )
    }
}

#[allow(clippy::too_many_arguments)]
fn conv_layer(
    name: &str,
    inputs: Vec<String>,
    k: usize,
    stride: usize,
    relu: bool,
    in_hw: usize,
    in_ch: usize,
    out_ch: usize,
) -> Layer {
    Layer {
        name: name.to_string(),
        op: Op::Conv,
        inputs,
        k,
        stride,
        relu,
        in_shape: vec![in_hw, in_hw, in_ch],
        out_shape: vec![in_hw.div_ceil(stride), in_hw.div_ceil(stride), out_ch],
        in_ch,
        out_ch,
    }
}

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f64) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| (rng.normal() * scale) as f32).collect())
}

/// Push the fixture's weights through the real compression pipeline:
/// prune (fine or coarse) then per-channel weight quantization.
fn compress_weights(rng: &mut Rng, weights: &mut Weights) {
    let algs = [PruneAlg::Level, PruneAlg::L1Ranked];
    let ratios = [0.0, 0.4, 0.85];
    for wt in weights.w.iter_mut() {
        let alg = algs[rng.below(algs.len())];
        let ratio = ratios[rng.below(ratios.len())];
        let sal = Tensor::full(wt.shape.clone(), 1.0);
        let chsq = vec![1.0f32; wt.out_channels(false)];
        let mut prng = Rng::new(rng.next_u64());
        let mut ctx = PruneCtx { saliency: &sal, chsq: &chsq, dwconv: false, rng: &mut prng };
        prune(wt, alg, ratio, &mut ctx);
        quantize_weights(wt, 2 + rng.below(7) as u32);
    }
}

fn gen_fixture(rng: &mut Rng) -> Fixture {
    let seed = rng.next_u64();
    let cin = 1 + rng.below(3);
    let classes = 2 + rng.below(3);
    let c1 = 2 + rng.below(3);
    let k1 = [1usize, 3][rng.below(2)];
    let dw_branch = rng.below(2) == 0;
    let with_concat = rng.below(2) == 0;
    // strided SAME padding is the geometry most likely to diverge
    // between kernels (asymmetric pads, div_ceil output dims), so the
    // trunk conv randomly downsamples; the branch pair also strides
    // when no concat pins its spatial dims to layer `a`'s
    let a_stride = 1 + rng.below(2);
    let b_stride = if with_concat { 1 } else { 1 + rng.below(2) };
    let a_hw = 6usize.div_ceil(a_stride);
    let b_hw = a_hw.div_ceil(b_stride);
    let n_ex = 3 + rng.below(4);
    let batch = 2 + rng.below(3);

    // graph: input -> a -> {b1, b2} -> add [-> concat(add, a)] -> gap -> f
    let mut layers = vec![
        conv_layer("a", vec!["input".into()], k1, a_stride, true, 6, cin, c1),
        conv_layer("b1", vec!["a".into()], 3, b_stride, rng.below(2) == 0, a_hw, c1, c1),
    ];
    if dw_branch {
        layers.push(Layer {
            name: "b2".into(),
            op: Op::DwConv,
            inputs: vec!["a".into()],
            k: 3,
            stride: b_stride,
            relu: rng.below(2) == 0,
            in_shape: vec![a_hw, a_hw, c1],
            out_shape: vec![b_hw, b_hw, c1],
            in_ch: c1,
            out_ch: c1,
        });
    } else {
        layers.push(conv_layer(
            "b2",
            vec!["a".into()],
            1,
            b_stride,
            rng.below(2) == 0,
            a_hw,
            c1,
            c1,
        ));
    }
    layers.push(Layer {
        name: "add".into(),
        op: Op::Add,
        inputs: vec!["b1".into(), "b2".into()],
        k: 1,
        stride: 1,
        relu: true,
        in_shape: vec![b_hw, b_hw, c1],
        out_shape: vec![b_hw, b_hw, c1],
        in_ch: c1,
        out_ch: c1,
    });
    let mut fc_in = c1;
    let mut gap_src = "add".to_string();
    if with_concat {
        // b_stride == 1 here, so `add` and `a` share spatial dims
        layers.push(Layer {
            name: "cat".into(),
            op: Op::Concat,
            inputs: vec!["add".into(), "a".into()],
            k: 1,
            stride: 1,
            relu: false,
            in_shape: vec![b_hw, b_hw, c1],
            out_shape: vec![b_hw, b_hw, 2 * c1],
            in_ch: c1,
            out_ch: 2 * c1,
        });
        fc_in = 2 * c1;
        gap_src = "cat".to_string();
    }
    layers.push(Layer {
        name: "gap".into(),
        op: Op::Gap,
        inputs: vec![gap_src],
        k: 1,
        stride: 1,
        relu: false,
        in_shape: vec![b_hw, b_hw, fc_in],
        out_shape: vec![fc_in],
        in_ch: fc_in,
        out_ch: fc_in,
    });
    layers.push(Layer {
        name: "f".into(),
        op: Op::Fc,
        inputs: vec!["gap".into()],
        k: 1,
        stride: 1,
        relu: false,
        in_shape: vec![fc_in],
        out_shape: vec![classes],
        in_ch: fc_in,
        out_ch: classes,
    });

    let prunable: Vec<String> = vec!["a".into(), "b1".into(), "b2".into(), "f".into()];
    let prunable_idx: HashMap<String, usize> =
        prunable.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
    let n_p = prunable.len();
    let arch = ModelArch {
        name: "confgraph".into(),
        dataset: "synth-conf".into(),
        input: [6, 6, cin],
        classes,
        batch,
        layers,
        prunable,
        prunable_idx,
        dep_groups: vec![],
        act_scales: (0..n_p).map(|_| rng.range(0.3, 1.0) as f32).collect(),
        act_signed: vec![true, false, false, false],
        acc_int8: 0.0,
        n_params: 0,
    };

    let w_shapes: Vec<Vec<usize>> = vec![
        vec![k1, k1, cin, c1],
        vec![3, 3, c1, c1],
        if dw_branch { vec![3, 3, 1, c1] } else { vec![1, 1, c1, c1] },
        vec![fc_in, classes],
    ];
    let out_chs = [c1, c1, c1, classes];
    let mut w = Vec::new();
    let mut b = Vec::new();
    let mut sal = Vec::new();
    let mut chsq = Vec::new();
    for (shape, &oc) in w_shapes.into_iter().zip(&out_chs) {
        w.push(rand_tensor(rng, shape.clone(), 0.5));
        b.push(rand_tensor(rng, vec![oc], 0.2));
        sal.push(Tensor::full(shape, 1.0));
        chsq.push(vec![1.0f32; oc]);
    }
    let mut weights = Weights { w, b, sal, chsq };
    compress_weights(rng, &mut weights);

    let act_bits: Vec<f32> = (0..n_p).map(|_| BITS[rng.below(BITS.len())]).collect();
    let images = rand_tensor(rng, vec![n_ex, 6, 6, cin], 0.8);
    let labels: Vec<i64> = (0..n_ex).map(|_| rng.below(classes) as i64).collect();
    Fixture { seed, arch, weights, act_bits, images, labels }
}

fn backend(fx: &Fixture, threads: usize, kernel: KernelKind) -> NativeBackend {
    let data =
        EvalData::from_arrays(&fx.arch, &fx.images, &fx.labels, 1000, fx.arch.batch).unwrap();
    NativeBackend::with_options(&fx.arch, data, threads, kernel).unwrap()
}

/// The stateless f32 reference forward, batch by batch, padded rows
/// dropped — the ground truth every kernel/engine combination must hit.
fn reference_logits(b: &NativeBackend, fx: &Fixture) -> Vec<f32> {
    let classes = fx.arch.classes;
    let batch = fx.arch.batch;
    let mut out = Vec::new();
    let n_batches = fx.labels.len().div_ceil(batch);
    for bi in 0..n_batches {
        let rows = (fx.labels.len() - bi * batch).min(batch);
        let full = b.logits(&fx.weights, &fx.act_bits, bi).unwrap();
        out.extend_from_slice(&full[..rows * classes]);
    }
    out
}

#[test]
fn int_logits_bit_identical_to_f32_reference_across_bits_and_threads() {
    forall("int == f32 == reference, threads {1,4}", gen_fixture, |fx| {
        let bi1 = backend(fx, 1, KernelKind::Int);
        let bi4 = backend(fx, 4, KernelKind::Int);
        let bf = backend(fx, 1, KernelKind::F32);
        let reference = reference_logits(&bf, fx);
        let li1 = bi1.engine_logits(&fx.weights, &fx.act_bits).unwrap();
        let li4 = bi4.engine_logits(&fx.weights, &fx.act_bits).unwrap();
        let lf = bf.engine_logits(&fx.weights, &fx.act_bits).unwrap();
        let ai = bi1.accuracy(&fx.weights, &fx.act_bits).unwrap();
        let af = bf.accuracy(&fx.weights, &fx.act_bits).unwrap();
        li1 == reference && li4 == reference && lf == reference && ai == af
    });
}

#[test]
fn int_kernel_sweeps_every_bit_width_uniformly() {
    // pin each paper bit-width explicitly (the sampled fixtures above
    // mix them per layer): uniform act_bits at 2/3/4/6/8 bits each
    // reproduce the reference bitwise
    forall("uniform bits {2,3,4,6,8}", gen_fixture, |fx| {
        let bi = backend(fx, 2, KernelKind::Int);
        let bf = backend(fx, 1, KernelKind::F32);
        BITS.iter().all(|&bits| {
            let uniform = vec![bits; fx.arch.prunable.len()];
            let fx_b = Fixture {
                seed: fx.seed,
                arch: fx.arch.clone(),
                weights: fx.weights.clone(),
                act_bits: uniform.clone(),
                images: fx.images.clone(),
                labels: fx.labels.clone(),
            };
            let reference = reference_logits(&bf, &fx_b);
            bi.engine_logits(&fx.weights, &uniform).unwrap() == reference
        })
    });
}

#[test]
fn int_kernel_matches_f32_after_arbitrary_invalidate_sequences() {
    forall("int incremental == f32 scratch across invalidates", gen_fixture, |fx| {
        let n = fx.arch.prunable.len();
        let inc = backend(fx, 1 + (fx.seed % 3) as usize, KernelKind::Int);
        let mut weights = fx.weights.clone();
        let mut bits = fx.act_bits.clone();
        let mut rng = Rng::new(fx.seed);
        for _round in 0..4 {
            match rng.below(3) {
                0 => {
                    // re-compress ONE layer (the RL-step pattern):
                    // fresh pruning mask + weight grid
                    let i = rng.below(n);
                    for v in weights.w[i].data.iter_mut() {
                        *v = *v * 1.5 + 0.01;
                    }
                    let sal = Tensor::full(weights.w[i].shape.clone(), 1.0);
                    let chsq = vec![1.0f32; weights.w[i].out_channels(false)];
                    let mut prng = Rng::new(rng.next_u64());
                    let mut ctx =
                        PruneCtx { saliency: &sal, chsq: &chsq, dwconv: false, rng: &mut prng };
                    prune(&mut weights.w[i], PruneAlg::Level, 0.5, &mut ctx);
                    quantize_weights(&mut weights.w[i], 2 + rng.below(7) as u32);
                    inc.invalidate(i);
                }
                1 => {
                    // change one layer's precision WITHOUT a hint — the
                    // engine's act-bits diff must re-pack that layer
                    let i = rng.below(n);
                    bits[i] = BITS[rng.below(BITS.len())];
                }
                _ => {
                    // episode reset: everything changes at once
                    for wt in weights.w.iter_mut() {
                        for v in wt.data.iter_mut() {
                            *v *= 0.8;
                        }
                    }
                    inc.invalidate_all();
                }
            }
            let scratch = backend(fx, 1, KernelKind::F32);
            let fx_now = Fixture {
                seed: fx.seed,
                arch: fx.arch.clone(),
                weights: weights.clone(),
                act_bits: bits.clone(),
                images: fx.images.clone(),
                labels: fx.labels.clone(),
            };
            let reference = reference_logits(&scratch, &fx_now);
            if inc.engine_logits(&weights, &bits).unwrap() != reference {
                return false;
            }
            if inc.accuracy(&weights, &bits).unwrap()
                != scratch.accuracy(&weights, &bits).unwrap()
            {
                return false;
            }
        }
        true
    });
}

#[test]
fn stats_record_kernel_and_pack_timings() {
    let mut rng = Rng::new(0xC0DE);
    let fx = gen_fixture(&mut rng);
    let bi = backend(&fx, 1, KernelKind::Int);
    let bf = backend(&fx, 1, KernelKind::F32);
    bi.accuracy(&fx.weights, &fx.act_bits).unwrap();
    bf.accuracy(&fx.weights, &fx.act_bits).unwrap();
    // a second query after an invalidate accumulates more phase time
    bi.invalidate(0);
    bf.invalidate(0);
    bi.accuracy(&fx.weights, &fx.act_bits).unwrap();
    bf.accuracy(&fx.weights, &fx.act_bits).unwrap();
    let si = bi.stats();
    let sf = bf.stats();
    assert_eq!(si.kernel, KernelKind::Int);
    assert_eq!(sf.kernel, KernelKind::F32);
    // the int engine packed (at least) the four prunable layers once
    assert!(si.pack_secs > 0.0, "int kernel never packed anything");
    assert_eq!(sf.pack_secs, 0.0, "f32 kernel must not pack");
    // both kernels account their prunable-layer evaluation time
    assert!(si.gemm_secs > 0.0);
    assert!(sf.gemm_secs > 0.0);
}

/// Raw-GEMM conformance for the blocked/tiled kernel: at every tile
/// width — including widths that leave 4x8-block, 8-lane, and scalar
/// remainders — `code_matmul_tiled` must be bitwise-equal to the
/// scalar int path AND to the dense f32 matmul, on shapes that probe
/// every remainder branch (n < 8, n = multiple of 8, 8 < n < 32,
/// n > 32 with tails, single row/col).
#[test]
fn blocked_gemm_bitwise_equal_to_scalar_and_f32_across_tiles() {
    let (lo, hi, step) = quant_params(4.0, 0.5, false);
    let grid = QuantGrid::new(lo, hi, step);
    let lut = grid.lut().unwrap();
    let mut rng = Rng::new(0xB10C);
    let shapes =
        [(1usize, 1usize, 1usize), (2, 7, 8), (3, 9, 33), (5, 40, 70), (4, 16, 32), (2, 5, 9)];
    for &(r, k, n) in &shapes {
        // ~30% exact-zero activations (post-ReLU pattern) + a third of
        // the weight rows fully pruned, so pack drops planes
        let codes = CodeMat {
            r,
            c: k,
            d: (0..r * k)
                .map(|_| if rng.uniform() < 0.3 { 0 } else { 1 + rng.below(grid.levels()) as i16 })
                .collect(),
        };
        let acts =
            Mat::from_vec(r, k, codes.d.iter().map(|&c| lut[(c + 1) as usize]).collect());
        let wdense: Vec<f32> = (0..k * n)
            .map(|i| if (i / n) % 3 == 0 { 0.0 } else { rng.normal() as f32 * 0.2 })
            .collect();
        let wmat = Mat::from_vec(k, n, wdense.clone());
        let packed = PackedMat::pack(k, n, &wdense);
        let y_f32 = acts.matmul(&wmat);
        let y_scalar = packed.code_matmul_scalar(&codes, &lut);
        assert_eq!(
            y_scalar.d.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y_f32.d.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "scalar int != f32 reference at shape ({r},{k},{n})"
        );
        for tile in [1usize, 3, 8, 17, DEFAULT_GEMM_TILE] {
            let y_tiled = packed.code_matmul_tiled(&codes, &lut, tile);
            assert_eq!(
                y_tiled.d.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y_scalar.d.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "blocked != scalar at shape ({r},{k},{n}), tile {tile}"
            );
        }
    }
}

/// Engine-level tile sweep: the full oracle (threads {1,4}) is bitwise
/// invariant under the process-wide GEMM tile override. Safe to run
/// concurrently with the other tests in this binary: every tile width
/// is bit-identical, so a racing reader only changes wall-clock.
#[test]
fn engine_logits_bitwise_invariant_under_gemm_tile_and_threads() {
    forall("engine invariant under gemm tile {1,3,8,17}", gen_fixture, |fx| {
        let bf = backend(fx, 1, KernelKind::F32);
        let reference = reference_logits(&bf, fx);
        let ok = [1usize, 3, 8, 17].iter().all(|&tile| {
            set_gemm_tile(tile);
            [1usize, 4].iter().all(|&threads| {
                [SchedKind::Static, SchedKind::Steal].iter().all(|&sched| {
                    let data = EvalData::from_arrays(
                        &fx.arch,
                        &fx.images,
                        &fx.labels,
                        1000,
                        fx.arch.batch,
                    )
                    .unwrap();
                    let bi = NativeBackend::with_sched(
                        &fx.arch,
                        data,
                        threads,
                        KernelKind::Int,
                        MemoConfig::default(),
                        sched,
                    )
                    .unwrap();
                    bi.engine_logits(&fx.weights, &fx.act_bits).unwrap() == reference
                })
            })
        });
        set_gemm_tile(0); // clear the override for the other tests
        ok
    });
}

/// Compress one layer of the fixture into a [`Candidate`] the way an
/// RL proposal batch would: perturb, re-prune, re-quantize.
fn gen_candidate(fx: &Fixture, rng: &mut Rng) -> Candidate {
    let li = rng.below(fx.arch.prunable.len());
    let mut wt = fx.weights.w[li].clone();
    for v in wt.data.iter_mut() {
        *v = *v * 1.3 + 0.02;
    }
    let sal = Tensor::full(wt.shape.clone(), 1.0);
    let chsq = vec![1.0f32; wt.out_channels(false)];
    let mut prng = Rng::new(rng.next_u64());
    let mut ctx = PruneCtx { saliency: &sal, chsq: &chsq, dwconv: false, rng: &mut prng };
    prune(&mut wt, PruneAlg::Level, 0.1 + 0.7 * rng.uniform() as f32, &mut ctx);
    let wbits = 2 + rng.below(7) as u32;
    quantize_weights(&mut wt, wbits);
    Candidate {
        layer: li,
        w: Arc::new(wt),
        b: Arc::new(fx.weights.b[li].clone()),
        bits: BITS[rng.below(BITS.len())],
    }
}

/// Batched candidate pricing must be bitwise-equal to the serial
/// one-at-a-time semantics (the `InferenceBackend` trait default:
/// invalidate -> swap layer -> score -> restore -> invalidate), on both
/// kernels, including duplicate-layer candidates — and must leave the
/// engine's incremental state untouched.
#[test]
fn batched_candidate_pricing_bitwise_equal_to_serial() {
    forall("batched == serial candidate pricing", gen_fixture, |fx| {
        let mut rng = Rng::new(fx.seed ^ 0xCA4D);
        let n_cands = 2 + rng.below(4);
        let cands: Vec<Candidate> =
            (0..n_cands).map(|_| gen_candidate(fx, &mut rng)).collect();
        for kernel in [KernelKind::Int, KernelKind::F32] {
            let b = backend(fx, 1 + (fx.seed % 3) as usize, kernel);
            let base_before = b.accuracy(&fx.weights, &fx.act_bits).unwrap();

            // serial reference: the trait-default swap loop, inlined
            // because NativeBackend overrides it with the batched path
            let mut w = fx.weights.clone();
            let mut bits = fx.act_bits.clone();
            let mut serial_acc = Vec::new();
            let mut serial_logits = Vec::new();
            for c in &cands {
                let (ow, ob, obits) =
                    (w.w[c.layer].clone(), w.b[c.layer].clone(), bits[c.layer]);
                b.invalidate(c.layer);
                w.w[c.layer] = (*c.w).clone();
                w.b[c.layer] = (*c.b).clone();
                bits[c.layer] = c.bits;
                serial_acc.push(b.accuracy(&w, &bits).unwrap());
                serial_logits.push(b.engine_logits(&w, &bits).unwrap());
                w.w[c.layer] = ow;
                w.b[c.layer] = ob;
                bits[c.layer] = obits;
                b.invalidate(c.layer);
            }

            let batch_acc = b.accuracy_batch(&fx.weights, &fx.act_bits, &cands).unwrap();
            let batch_logits =
                b.engine_logits_batch(&fx.weights, &fx.act_bits, &cands).unwrap();
            if batch_acc.iter().map(|a| a.to_bits()).collect::<Vec<_>>()
                != serial_acc.iter().map(|a| a.to_bits()).collect::<Vec<_>>()
            {
                return false;
            }
            if batch_logits != serial_logits {
                return false;
            }
            // the batch never disturbs the engine's incremental state
            if b.accuracy(&fx.weights, &fx.act_bits).unwrap() != base_before {
                return false;
            }
        }
        true
    });
}

#[test]
fn degenerate_calibration_scale_falls_back_to_f32_per_layer() {
    // a zero act_scale makes fake_quant a pass-through; the int kernel
    // cannot code that layer and must fall back to the f32 path for it
    // (and only it) — logits still bit-identical to the reference
    let mut rng = Rng::new(0xFA11);
    let mut fx = gen_fixture(&mut rng);
    fx.arch.act_scales[1] = 0.0;
    let bi = backend(&fx, 2, KernelKind::Int);
    let bf = backend(&fx, 1, KernelKind::F32);
    let reference = reference_logits(&bf, &fx);
    assert_eq!(bi.engine_logits(&fx.weights, &fx.act_bits).unwrap(), reference);
    assert_eq!(bf.engine_logits(&fx.weights, &fx.act_bits).unwrap(), reference);
}
