//! NativeBackend correctness against hand-computed forward passes.
//!
//! Unlike tests/integration.rs these need NO artifacts: the fixture
//! models, weights and data are built in-memory, so they run in every
//! environment (they are the CI-proof of the default reward oracle).
//!
//! All expected numbers below are derived by hand from the exported
//! graph semantics (python/compile/model.py + kernels/ref.py): SAME
//! conv, k×k/VALID maxpool, GAP, [in,out] fc, and per-layer Laplace
//! fake-quant of prunable-layer inputs with
//! `alpha = act_scale · clip(bits)`, `step = alpha / (2^bits - 1)`
//! (unsigned) or `2·alpha / (2^bits - 1)` (signed).

use hapq::env::{Action, CompressionEnv};
use hapq::hw::energy::EnergyModel;
use hapq::hw::mac_sim::RqTable;
use hapq::hw::Accel;
use hapq::io::json;
use hapq::model::{ModelArch, Weights};
use hapq::runtime::{EvalData, InferenceBackend, InferenceSession, NativeBackend};
use hapq::tensor::Tensor;

fn close(a: f32, b: f32, tol: f32, what: &str) {
    assert!((a - b).abs() <= tol, "{what}: {a} vs {b} (tol {tol})");
}

// ---------------------------------------------------------------------------
// Fixture 1: conv(1x1, w=2, b=-0.4, relu) -> gap -> fc([1,-1], b=[0,0.25])
// on 2x2x1 inputs, act_scales = 1/2.83 so the 2-bit grid is exactly
// {0, 1/3, 2/3, 1}.

const FIX1: &str = r#"{
  "name": "fix1", "dataset": "synth-fix", "input": [2, 2, 1], "classes": 2,
  "batch": 2,
  "layers": [
    {"name": "c1", "op": "conv", "inputs": ["input"], "k": 1, "stride": 1,
     "relu": true, "in_shape": [2,2,1], "out_shape": [2,2,1], "in_ch": 1,
     "out_ch": 1},
    {"name": "gap", "op": "gap", "inputs": ["c1"], "in_shape": [2,2,1],
     "out_shape": [1]},
    {"name": "f1", "op": "fc", "inputs": ["gap"], "relu": false,
     "in_shape": [1], "out_shape": [2], "in_ch": 1, "out_ch": 2}
  ],
  "prunable": ["c1", "f1"],
  "dep_groups": [],
  "act_scales": [0.3533568904593639, 0.3533568904593639],
  "act_signed": [false, false],
  "acc_int8": 1.0, "n_params": 5
}"#;

fn fix1() -> (ModelArch, Weights) {
    let arch = ModelArch::from_json(&json::parse(FIX1).unwrap()).unwrap();
    let weights = Weights {
        w: vec![
            Tensor::new(vec![1, 1, 1, 1], vec![2.0]),
            Tensor::new(vec![1, 2], vec![1.0, -1.0]),
        ],
        b: vec![
            Tensor::new(vec![1], vec![-0.4]),
            Tensor::new(vec![2], vec![0.0, 0.25]),
        ],
        sal: vec![Tensor::full(vec![1, 1, 1, 1], 1.0), Tensor::full(vec![1, 2], 1.0)],
        chsq: vec![vec![1.0], vec![1.0, 1.0]],
    };
    (arch, weights)
}

fn fix1_data(labels: Vec<i64>) -> (hapq::model::ModelArch, EvalData) {
    let (arch, _) = fix1();
    // im0 ramps up, im1 stays in the lowest 2-bit quantization bin
    let images = Tensor::new(
        vec![2, 2, 2, 1],
        vec![
            0.2, 0.4, 0.6, 0.8, // im0
            0.05, 0.1, 0.15, 0.1, // im1
        ],
    );
    let data = EvalData::from_arrays(&arch, &images, &labels, 16, arch.batch).unwrap();
    (arch, data)
}

fn fix1_backend(labels: Vec<i64>) -> NativeBackend {
    let (arch, data) = fix1_data(labels);
    NativeBackend::new(&arch, data).unwrap()
}

/// Same fixture with an explicit engine worker count.
fn fix1_backend_threads(labels: Vec<i64>, threads: usize) -> NativeBackend {
    let (arch, data) = fix1_data(labels);
    NativeBackend::with_threads(&arch, data, threads).unwrap()
}

#[test]
fn native_matches_hand_computed_forward_2bit() {
    // 2-bit grid {0, 1/3, 2/3, 1} (alpha = 0.35336 * 2.83 = 1.0):
    //   im0 quantizes to [1/3, 1/3, 2/3, 2/3]
    //   -> conv y = 2*q - 0.4 = [4/15.., ..], relu keeps all
    //   -> gap = (0.2667+0.2667+0.9333+0.9333)/4 = 0.6
    //   -> f1 input quant: 0.6 -> 1.8 steps -> 2 steps = 2/3
    //   -> logits = [2/3, -2/3 + 0.25]
    //   im1 quantizes to all-zero -> conv = -0.4 -> relu 0 -> logits [0, 0.25]
    let (_, weights) = fix1();
    let backend = fix1_backend(vec![0, 1]);
    let logits = backend.logits(&weights, &[2.0, 2.0], 0).unwrap();
    close(logits[0], 2.0 / 3.0, 1e-4, "im0 logit 0");
    close(logits[1], -2.0 / 3.0 + 0.25, 1e-4, "im0 logit 1");
    close(logits[2], 0.0, 1e-6, "im1 logit 0");
    close(logits[3], 0.25, 1e-6, "im1 logit 1");
    // im0 -> class 0, im1 -> class 1
    let acc = backend.accuracy(&weights, &[2.0, 2.0]).unwrap();
    assert_eq!(acc, 1.0);
}

#[test]
fn native_accuracy_counts_misses() {
    let (_, weights) = fix1();
    // swap the labels: both rows now wrong vs the policy above? no —
    // im0 predicts 0, im1 predicts 1; labels [1, 1] score 0.5
    let backend = fix1_backend(vec![1, 1]);
    let acc = backend.accuracy(&weights, &[2.0, 2.0]).unwrap();
    assert_eq!(acc, 0.5);
    let backend = fix1_backend(vec![1, 0]);
    let acc = backend.accuracy(&weights, &[2.0, 2.0]).unwrap();
    assert_eq!(acc, 0.0);
}

#[test]
fn native_8bit_keeps_the_argmax() {
    // at 8 bits the grid error is < step = alpha/255 ≈ 0.0137 — far
    // below the fixture's logit margins, so predictions are unchanged
    let (_, weights) = fix1();
    let backend = fix1_backend(vec![0, 1]);
    assert_eq!(backend.accuracy(&weights, &[8.0, 8.0]).unwrap(), 1.0);
    // mixed precision per layer as the RL agent would set it
    assert_eq!(backend.accuracy(&weights, &[2.0, 8.0]).unwrap(), 1.0);
}

#[test]
fn native_backend_validates_inputs() {
    let (_, weights) = fix1();
    let backend = fix1_backend(vec![0, 1]);
    assert!(backend.accuracy(&weights, &[8.0]).is_err()); // wrong len
    assert_eq!(backend.n_prunable(), 2);
    assert_eq!(backend.n_examples(), 2);
    assert_eq!(backend.batch(), 2);
    assert_eq!(backend.name(), "native");
    // the cache hints mark engine state dirty (and tolerate bad indices)
    backend.invalidate(0);
    backend.invalidate(99);
    backend.invalidate_all();
    assert_eq!(backend.accuracy(&weights, &[2.0, 2.0]).unwrap(), 1.0);
}

#[test]
fn engine_resumes_after_invalidate_matching_fresh_backend() {
    // mutate one layer mid-session (as the RL env does), hint the
    // engine, and require the incremental answer to match a backend
    // built from scratch on the mutated weights — bitwise.
    let (_, mut weights) = fix1();
    let backend = fix1_backend(vec![0, 1]);
    let bits = [2.0f32, 2.0];
    let a0 = backend.accuracy(&weights, &bits).unwrap();
    assert_eq!(a0, 1.0);
    // flip the classifier weights: predictions for im0 flip to class 1
    weights.w[1].data = vec![-1.0, 1.0];
    backend.invalidate(1);
    let a1 = backend.accuracy(&weights, &bits).unwrap();
    let fresh = fix1_backend(vec![0, 1]);
    assert_eq!(a1, fresh.accuracy(&weights, &bits).unwrap());
    assert_eq!(a1, 0.5); // im0 now wrong, im1 still right
    // engine logits equal the reference from-scratch forward bitwise
    let engine = backend.engine_logits(&weights, &bits).unwrap();
    let reference = backend.logits(&weights, &bits, 0).unwrap();
    assert_eq!(engine, reference);
}

#[test]
fn engine_detects_act_bits_changes_without_a_hint() {
    // precision changes are detected by the engine's own act-bits diff,
    // so a missing invalidate() cannot produce stale results
    let (_, weights) = fix1();
    let backend = fix1_backend(vec![0, 1]);
    let a2 = backend.accuracy(&weights, &[2.0, 2.0]).unwrap();
    let a8 = backend.accuracy(&weights, &[8.0, 8.0]).unwrap();
    let fresh = fix1_backend(vec![0, 1]);
    assert_eq!(a8, fresh.accuracy(&weights, &[8.0, 8.0]).unwrap());
    let e2 = backend.engine_logits(&weights, &[2.0, 2.0]).unwrap();
    let f2 = fresh.engine_logits(&weights, &[2.0, 2.0]).unwrap();
    assert_eq!(e2, f2);
    let _ = a2;
}

#[test]
fn engine_reuses_clean_layers_and_reports_stats() {
    let (_, weights) = fix1();
    let backend = fix1_backend_threads(vec![0, 1], 1);
    // fix1 graph has 3 nodes: c1 -> gap -> f1
    backend.accuracy(&weights, &[2.0, 2.0]).unwrap();
    let s = backend.stats();
    assert_eq!((s.layers_computed, s.layers_reused), (3, 0));
    assert_eq!(s.threads, 1);
    // invalidating only the classifier resumes the pass at f1
    backend.invalidate(1);
    backend.accuracy(&weights, &[2.0, 2.0]).unwrap();
    let s = backend.stats();
    assert_eq!((s.layers_computed, s.layers_reused), (4, 2));
    // an unchanged query serves everything from the checkpoint cache
    backend.accuracy(&weights, &[2.0, 2.0]).unwrap();
    let s = backend.stats();
    assert_eq!((s.layers_computed, s.layers_reused), (4, 5));
    assert!((s.cache_hit_rate() - 5.0 / 9.0).abs() < 1e-12);
}

#[test]
fn threaded_engine_is_bit_identical_to_single_thread() {
    let (_, weights) = fix1();
    let b1 = fix1_backend_threads(vec![0, 1], 1);
    let b4 = fix1_backend_threads(vec![0, 1], 4);
    for bits in [[2.0f32, 2.0], [2.0, 8.0], [8.0, 8.0]] {
        assert_eq!(
            b1.accuracy(&weights, &bits).unwrap(),
            b4.accuracy(&weights, &bits).unwrap()
        );
        assert_eq!(
            b1.engine_logits(&weights, &bits).unwrap(),
            b4.engine_logits(&weights, &bits).unwrap()
        );
    }
    assert_eq!(b4.stats().threads, 4);
}

// ---------------------------------------------------------------------------
// Fixture 2: dwconv -> maxpool -> flatten -> fc(identity) on 2x2x2,
// signed 8-bit input grid (step 19.8/255), exercising the remaining ops.

const FIX2: &str = r#"{
  "name": "fix2", "dataset": "synth-fix", "input": [2, 2, 2], "classes": 2,
  "batch": 1,
  "layers": [
    {"name": "d1", "op": "dwconv", "inputs": ["input"], "k": 1, "stride": 1,
     "relu": false, "in_shape": [2,2,2], "out_shape": [2,2,2], "in_ch": 2,
     "out_ch": 2},
    {"name": "p1", "op": "maxpool", "inputs": ["d1"], "k": 2,
     "in_shape": [2,2,2], "out_shape": [1,1,2]},
    {"name": "flat", "op": "flatten", "inputs": ["p1"], "in_shape": [1,1,2],
     "out_shape": [2]},
    {"name": "f1", "op": "fc", "inputs": ["flat"], "relu": false,
     "in_shape": [2], "out_shape": [2], "in_ch": 2, "out_ch": 2}
  ],
  "prunable": ["d1", "f1"],
  "dep_groups": [],
  "act_scales": [1.0, 1.0],
  "act_signed": [true, false],
  "acc_int8": 1.0, "n_params": 10
}"#;

#[test]
fn native_dwconv_maxpool_flatten_hand_values() {
    let arch = ModelArch::from_json(&json::parse(FIX2).unwrap()).unwrap();
    let weights = Weights {
        w: vec![
            // dwconv [1,1,1,2]: channel 0 x1, channel 1 x2
            Tensor::new(vec![1, 1, 1, 2], vec![1.0, 2.0]),
            // fc identity
            Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]),
        ],
        b: vec![
            Tensor::new(vec![2], vec![0.0, 0.0]),
            Tensor::new(vec![2], vec![0.0, 0.0]),
        ],
        sal: vec![Tensor::full(vec![1, 1, 1, 2], 1.0), Tensor::full(vec![2, 2], 1.0)],
        chsq: vec![vec![1.0, 1.0], vec![1.0, 1.0]],
    };
    // positions p0..p3 with channels (c0, c1)
    let images = Tensor::new(
        vec![1, 2, 2, 2],
        vec![0.5, -0.3, 1.0, 0.7, 0.25, 0.9, -0.5, 0.2],
    );
    let data = EvalData::from_arrays(&arch, &images, &[1], 16, arch.batch).unwrap();
    let backend = NativeBackend::new(&arch, data).unwrap();
    // signed 8-bit grid: step = 2*9.9/255 = 0.0776471; inputs snap to
    //   c0: [0.4658824, 1.0094118, 0.2329412, -0.4658824]
    //   c1: [-0.3105882, 0.6988235, 0.9317647, 0.2329412]
    // dwconv: c0 x1, c1 x2; maxpool picks (1.0094118, 1.8635294);
    // f1's unsigned 8-bit grid (step 0.0388235) holds both exactly.
    let logits = backend.logits(&weights, &[8.0, 8.0], 0).unwrap();
    close(logits[0], 1.0094118, 1e-4, "pooled c0");
    close(logits[1], 1.8635294, 1e-4, "pooled c1 (x2)");
    assert_eq!(backend.accuracy(&weights, &[8.0, 8.0]).unwrap(), 1.0);
}

// ---------------------------------------------------------------------------
// The whole Fig-3 loop on the native backend — prune + quantize +
// energy model + inference + LUT reward, no artifacts involved.

#[test]
fn env_episode_runs_on_native_backend() {
    let (arch, weights) = fix1();
    let images = Tensor::new(
        vec![4, 2, 2, 1],
        vec![
            0.2, 0.4, 0.6, 0.8, //
            0.05, 0.1, 0.15, 0.1, //
            0.7, 0.7, 0.2, 0.3, //
            0.9, 0.8, 0.7, 0.6,
        ],
    );
    let labels = vec![0i64, 1, 0, 0];
    let data = EvalData::from_arrays(&arch, &images, &labels, 16, arch.batch).unwrap();
    let session =
        InferenceSession::from_backend(Box::new(NativeBackend::new(&arch, data).unwrap()));
    assert_eq!(session.backend_name(), "native");
    assert_eq!(session.n_examples, 4);
    let energy = EnergyModel::new(
        arch.layer_dims().unwrap(),
        Accel::default(),
        RqTable::compute(400, 3),
    );
    let mut env = CompressionEnv::new(arch, weights, energy, session, 7).unwrap();
    assert!(env.baseline_acc > 0.0);
    let n = env.n_layers();
    assert_eq!(n, 2);
    let mut state = env.reset();
    assert_eq!(state.len(), hapq::env::STATE_DIM);
    for t in 0..n {
        let step = env
            .step(Action { ratio: 0.3, bits: 0.8, alg: t % 7 })
            .unwrap();
        assert!(step.reward.is_finite());
        assert!((0.0..=1.0).contains(&step.accuracy));
        assert_eq!(step.done, t == n - 1);
        state = step.state;
    }
    let _ = state;
    assert_eq!(env.n_evals, n as u64);
    // replaying a full config through the same oracle also works
    let sol = env
        .evaluate_config(&vec![Action { ratio: 0.0, bits: 1.0, alg: 0 }; n])
        .unwrap();
    assert!(sol.reward.is_finite());
    assert!(sol.energy_gain.abs() < 0.2); // 8-bit no-prune ≈ baseline
}
