//! Integration tests over the full artifact contract: JSON/NPZ loading,
//! inference of the exported models (native interpreter by default;
//! PJRT-specific round-trips live in the feature-gated module at the
//! bottom), the compression env, and a miniature composite-RL run. All
//! require `make artifacts` to have run (they are skipped with a notice
//! otherwise, so plain `cargo test` still passes in a fresh checkout).
//! Backend-independent hand-computed-fixture tests live in
//! `tests/native_backend.rs` and always run.

use std::path::PathBuf;

use hapq::config::RunConfig;
use hapq::coordinator::Coordinator;
use hapq::env::Action;
use hapq::pruning::PruneAlg;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn coord(reward_subset: usize) -> Option<Coordinator> {
    artifacts()?;
    Some(
        Coordinator::new(RunConfig {
            reward_subset,
            test_subset: 256,
            mac_samples: 1500,
            ..RunConfig::default()
        })
        .expect("coordinator"),
    )
}

#[test]
fn dense_inference_matches_exported_accuracy() {
    let Some(c) = coord(256) else { return };
    // the env's baseline accuracy (8-bit activations) should be within a
    // few points of the accuracy the exporter recorded on the test set
    let env = c.build_env("vgg11").unwrap();
    let (arch, _, _) = c.load_arch("vgg11").unwrap();
    assert!(
        (env.baseline_acc - arch.acc_int8).abs() < 0.1,
        "val-subset acc {} vs exported test acc {}",
        env.baseline_acc,
        arch.acc_int8
    );
}

#[test]
fn episode_walks_all_layers_and_rewards_are_lut_bounded() {
    let Some(c) = coord(64) else { return };
    let mut env = c.build_env("vgg11").unwrap();
    let n = env.n_layers();
    let mut s = env.reset();
    assert_eq!(s.len(), hapq::env::STATE_DIM);
    for t in 0..n {
        let step = env
            .step(Action { ratio: 0.2, bits: 0.9, alg: t % 7 })
            .unwrap();
        assert!(step.reward.is_finite());
        assert!(step.reward <= 10.0 && step.reward >= -9.0, "r={}", step.reward);
        assert!((0.0..=1.0).contains(&step.accuracy));
        assert_eq!(step.done, t == n - 1);
        s = step.state;
    }
    let _ = s;
}

#[test]
fn more_compression_more_energy_gain() {
    let Some(c) = coord(64) else { return };
    let mut env = c.build_env("vgg13").unwrap();
    let n = env.n_layers();
    let mk = |r: f64, b: f64| vec![Action { ratio: r, bits: b, alg: PruneAlg::L1Ranked.index() }; n];
    let light = env.evaluate_config(&mk(0.1, 1.0)).unwrap();
    let heavy = env.evaluate_config(&mk(0.6, 0.2)).unwrap();
    assert!(heavy.energy_gain > light.energy_gain);
    assert!(heavy.acc_loss >= light.acc_loss - 0.02);
}

#[test]
fn dependency_groups_respected_on_resnet() {
    let Some(c) = coord(64) else { return };
    let mut env = c.build_env("resnet18").unwrap();
    let n = env.n_layers();
    // all layers coarse-pruned: group members must end with identical masks
    let actions = vec![Action { ratio: 0.4, bits: 1.0, alg: PruneAlg::L1Ranked.index() }; n];
    let sol = env.evaluate_config(&actions).unwrap();
    let (arch, _, _) = c.load_arch("resnet18").unwrap();
    let (w, _) = env.compressed();
    for group in &arch.dep_groups {
        let masks: Vec<Vec<bool>> = group
            .iter()
            .map(|name| {
                let i = arch.pidx(name);
                let t = &w.w[i];
                let l1 = t.channel_l1(false);
                l1.iter().map(|&x| x == 0.0).collect()
            })
            .collect();
        for m in &masks[1..] {
            assert_eq!(m, &masks[0], "group {group:?} masks diverge");
        }
    }
    // at least one layer got its action overridden by the §4.1 rule
    assert!(sol.per_layer.iter().any(|a| a.overridden));
}

#[test]
fn classifier_layer_never_coarse_pruned() {
    let Some(c) = coord(64) else { return };
    let mut env = c.build_env("vgg11").unwrap();
    let n = env.n_layers();
    let actions = vec![Action { ratio: 0.5, bits: 1.0, alg: PruneAlg::L1Ranked.index() }; n];
    let sol = env.evaluate_config(&actions).unwrap();
    let last = sol.per_layer.last().unwrap();
    assert!(!last.alg.coarse(), "classifier was coarse-pruned: {last:?}");
    assert!(last.overridden);
}

#[test]
fn quantization_only_high_bits_keeps_accuracy() {
    let Some(c) = coord(256) else { return };
    let mut env = c.build_env("vgg11").unwrap();
    let n = env.n_layers();
    let sol = env
        .evaluate_config(&vec![Action { ratio: 0.0, bits: 1.0, alg: 0 }; n])
        .unwrap();
    assert!(sol.acc_loss < 0.03, "8-bit W+A quant lost {}", sol.acc_loss);
    assert!(sol.energy_gain.abs() < 0.05);
    // quantization-only gains are bounded by the compute share of total
    // energy (mini models are memory-dominated — EXPERIMENTS.md §F2a):
    // require gains to exist and to grow as precision drops
    let sol6 = env
        .evaluate_config(&vec![Action { ratio: 0.0, bits: 4.0 / 6.0, alg: 0 }; n])
        .unwrap();
    let sol2 = env
        .evaluate_config(&vec![Action { ratio: 0.0, bits: 0.0, alg: 0 }; n])
        .unwrap();
    assert!(sol6.energy_gain > 0.005, "6-bit quant should save energy: {}", sol6.energy_gain);
    assert!(sol2.energy_gain > sol6.energy_gain, "2-bit must beat 6-bit");
}

#[test]
fn tiny_composite_run_improves_over_random() {
    let Some(mut c) = coord(64) else { return };
    c.cfg.episodes = 14;
    c.cfg.warmup = 4;
    let report = c.compress("vgg11", false).unwrap();
    // with a tiny budget we only require sanity: a valid solution with
    // finite reward, some energy gain, and the curve recorded
    assert_eq!(report.reward_curve.len(), 14);
    assert!(report.best.energy_gain > 0.0);
    assert!(report.best.reward.is_finite());
    assert!(report.test_acc_dense > 0.8);
}

#[test]
fn baselines_smoke_on_vgg11() {
    let Some(mut c) = coord(64) else { return };
    c.cfg.episodes = 6;
    c.cfg.warmup = 2;
    for method in ["amc", "haq", "asqj", "opq", "nsga2"] {
        let r = c.run_baseline("vgg11", method).unwrap();
        assert!(r.best.reward.is_finite(), "{method}");
        assert!(r.evals > 0, "{method}");
        // uniform accounting (EXPERIMENTS.md): every method's JSON
        // carries evals + wall_secs through the shared SearchDriver
        let v = hapq::io::json::parse(&r.to_json().to_string()).unwrap();
        assert!(v.req("evals").unwrap().as_f64().unwrap() > 0.0, "{method}");
        assert!(v.req("wall_secs").unwrap().as_f64().unwrap() > 0.0, "{method}");
        assert_eq!(v.req("seed").unwrap().as_f64().unwrap(), c.cfg.seed as f64, "{method}");
    }
}

#[test]
fn report_json_roundtrips() {
    let Some(mut c) = coord(64) else { return };
    c.cfg.episodes = 4;
    c.cfg.warmup = 1;
    c.cfg.out = std::env::temp_dir().join("hapq_it_results");
    let report = c.compress("vgg11", false).unwrap();
    let path = c.save_report(&report).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let v = hapq::io::json::parse(&text).unwrap();
    assert_eq!(v.req("model").unwrap().as_str().unwrap(), "vgg11");
    assert_eq!(
        v.req("per_layer").unwrap().as_arr().unwrap().len(),
        report.best.per_layer.len()
    );
    // measurement conventions: oracle threads + cache hit rate are part
    // of every run JSON (EXPERIMENTS.md)
    assert!(v.req("threads").unwrap().as_f64().unwrap() >= 1.0);
    let hit = v.req("cache_hit_rate").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&hit), "cache_hit_rate {hit} out of range");
    // the RL walk dirties one layer per step, so the engine must have
    // reused a substantial share of checkpointed activations
    assert!(hit > 0.0, "incremental engine never reused a layer");
    // uniform budget accounting: compress reports carry the same
    // evals/wall_secs/seed fields the baselines do
    assert!(v.req("evals").unwrap().as_f64().unwrap() > 0.0);
    assert!(v.req("wall_secs").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(v.req("seed").unwrap().as_f64().unwrap(), c.cfg.seed as f64);
    // the kernel + its phase timings ride along (EXPERIMENTS.md) so
    // wall-clock comparisons can control for the compute path
    assert_eq!(v.req("kernel").unwrap().as_str().unwrap(), c.cfg.kernel.name());
    assert!(v.req("pack_secs").unwrap().as_f64().unwrap() >= 0.0);
    assert!(v.req("gemm_secs").unwrap().as_f64().unwrap() > 0.0);
    // the hardware target and its cost-query phase timer ride along so
    // cross-target sweeps are auditable from the JSON alone; an `ours`
    // run prices every step, so the timer must have accumulated
    assert_eq!(v.req("hw").unwrap().as_str().unwrap(), c.cfg.hw);
    assert!(v.req("hw_s").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn hw_flag_selects_target_end_to_end() {
    let Some(mut c) = coord(64) else { return };
    c.cfg.hw = "mcu".to_string();
    let env = c.build_env("vgg11").unwrap();
    assert_eq!(env.cost.model().target.name, "mcu");
    // a different target is a genuinely different cost surface
    let (arch, _, _) = c.load_arch("vgg11").unwrap();
    let e64 = hapq::hw::energy::EnergyModel::for_target(
        arch.layer_dims().unwrap(),
        &hapq::hw::target::HwTarget::builtin("eyeriss-64").unwrap(),
        c.rq.clone(),
    );
    assert_ne!(
        env.cost.model().baseline().to_bits(),
        e64.baseline().to_bits(),
        "mcu and eyeriss-64 priced the dense model identically"
    );
    // unknown names fail fast, before any search starts
    c.cfg.hw = "not-a-target".to_string();
    assert!(c.build_env("vgg11").is_err());
}

#[test]
fn perf_and_hw_json_emit_the_metrics_snapshot_schema() {
    let Some(_) = artifacts() else { return };
    let bin = env!("CARGO_BIN_EXE_hapq");

    // `hapq perf --json`: one MetricsRegistry snapshot over all live
    // stat sources (PhaseTimers, RuntimeStats, CostCache + perf's own)
    let out = std::process::Command::new(bin)
        .args(["perf", "--model", "vgg11", "--reward-subset", "64", "--json"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "perf --json failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let v = hapq::io::json::parse(std::str::from_utf8(&out.stdout).unwrap().trim()).unwrap();
    assert_eq!(
        v.req("schema").unwrap().as_usize().unwrap() as u64,
        hapq::telemetry::SCHEMA
    );
    let counters = v.req("counters").unwrap();
    assert!(counters.req("env.steps").unwrap().as_usize().unwrap() > 0);
    assert!(counters.req("hw.queries").unwrap().as_usize().unwrap() > 0);
    assert!(counters.req("exec.layers_computed").unwrap().as_usize().unwrap() > 0);
    let hist = v.req("histograms").unwrap().req("perf.episode_secs").unwrap();
    assert_eq!(hist.req("count").unwrap().as_usize().unwrap(), 10);
    assert!(hist.req("p50").unwrap().as_f64().unwrap() > 0.0);
    assert!(
        hist.req("max").unwrap().as_f64().unwrap()
            >= hist.req("p95").unwrap().as_f64().unwrap()
    );
    let labels = v.req("labels").unwrap();
    assert_eq!(labels.req("perf.model").unwrap().as_str().unwrap(), "vgg11");
    assert!(!labels.req("exec.kernel").unwrap().as_str().unwrap().is_empty());

    // `hapq hw --json`: same snapshot schema from the pure cost model —
    // one gauge quartet per built-in target
    let out = std::process::Command::new(bin)
        .args(["hw", "--model", "vgg11", "--json"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "hw --json failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let v = hapq::io::json::parse(std::str::from_utf8(&out.stdout).unwrap().trim()).unwrap();
    assert_eq!(
        v.req("schema").unwrap().as_usize().unwrap() as u64,
        hapq::telemetry::SCHEMA
    );
    let labels = v.req("labels").unwrap();
    assert_eq!(labels.req("hw.model").unwrap().as_str().unwrap(), "vgg11");
    let target = labels.req("hw.target").unwrap().as_str().unwrap().to_string();
    let gauges = v.req("gauges").unwrap();
    assert!(gauges.req("hw.reference.sparsity").unwrap().as_f64().unwrap() > 0.0);
    for metric in ["baseline_energy", "dense_cycles", "energy_gain", "latency_gain"] {
        let key = format!("hw.{target}.{metric}");
        assert!(
            gauges.get(&key).is_some(),
            "hw --json missing gauge {key} for the selected target"
        );
    }
}

// ---------------------------------------------------------------------------
// PJRT-specific round trips: compiled only with `--features pjrt`, and
// they additionally skip unless both artifacts exist and a *real* xla
// binding is linked (the in-tree stub errors on client construction —
// rust/vendor/README.md).

#[cfg(feature = "pjrt")]
mod pjrt_roundtrips {
    use super::*;
    use hapq::runtime::{literal_f32, BackendKind, InferenceSession, Runtime, Split};

    fn runtime() -> Option<Runtime> {
        match Runtime::cpu() {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("SKIP: no PJRT runtime linked ({e})");
                None
            }
        }
    }

    #[test]
    fn qmatmul_kernel_hlo_loads_and_runs() {
        let Some(dir) = artifacts() else { return };
        let Some(rt) = runtime() else { return };
        let exe = rt.load_hlo(&dir.join("qmatmul_pallas.hlo.txt")).unwrap();
        // x: 64x48 ones scaled, w: 48x32 identity-ish
        let x = literal_f32(&[64, 48], &vec![0.5f32; 64 * 48]).unwrap();
        let mut wdat = vec![0f32; 48 * 32];
        for i in 0..32 {
            wdat[i * 32 + i] = 1.0;
        }
        let w = literal_f32(&[48, 32], &wdat).unwrap();
        // grid [0, 2] with step for 4 bits
        let lo = literal_f32(&[], &[0.0]).unwrap();
        let hi = literal_f32(&[], &[2.0]).unwrap();
        let step = literal_f32(&[], &[2.0 / 15.0]).unwrap();
        let out = exe.run(&[x, w, lo, hi, step]).unwrap();
        let v: Vec<f32> = out.to_vec().unwrap();
        assert_eq!(v.len(), 64 * 32);
        // each output = quantized(0.5) once per identity column
        let q = (0.5f32 / (2.0 / 15.0)).round() * (2.0 / 15.0);
        assert!((v[0] - q).abs() < 1e-5, "{} vs {}", v[0], q);
    }

    #[test]
    fn pallas_variant_matches_lax_variant() {
        let Some(c) = coord(64) else { return };
        if runtime().is_none() {
            return;
        }
        let entry = c.entry("vgg11").unwrap().clone();
        let Some(pallas) = entry.pallas_hlo.clone() else {
            eprintln!("SKIP: no pallas artifact");
            return;
        };
        let (arch, weights, e) = c.load_arch("vgg11").unwrap();
        let data = c.cfg.artifacts.join(format!("{}.data.npz", e.dataset));
        let bits = vec![5.0f32; arch.prunable.len()];
        let lax = InferenceSession::open(
            BackendKind::Pjrt,
            &arch,
            Some(&c.cfg.artifacts.join(&e.hlo)),
            &data,
            Split::Test,
            64,
            None,
            1,
        )
        .unwrap();
        let pal = InferenceSession::open(
            BackendKind::Pjrt,
            &arch,
            Some(&c.cfg.artifacts.join(&pallas)),
            &data,
            Split::Test,
            64,
            Some(entry.pallas_batch),
            1,
        )
        .unwrap();
        let a1 = lax.accuracy(&weights, &bits).unwrap();
        let a2 = pal.accuracy(&weights, &bits).unwrap();
        assert!(
            (a1 - a2).abs() < 1e-9,
            "L1 pallas path ({a2}) != XLA path ({a1}) on identical examples"
        );
    }
}
