//! Offline stand-in for the `anyhow` crate (the registry is not
//! reachable from the build environment — see rust/vendor/README.md).
//!
//! Implements exactly the subset hapq uses, with the same semantics:
//!
//! * [`Error`]: an opaque error that any `std::error::Error` converts
//!   into via `?`, carrying the full `source()` chain;
//! * [`Result<T>`] with the `E = Error` default;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros with inline format
//!   captures;
//! * the [`Context`] extension trait (`.context(..)` /
//!   `.with_context(..)`) on both `Result` and `Option`;
//! * `{}` displays the outermost message, `{:#}` the whole chain
//!   joined by `: `, and `{:?}` an anyhow-style report with a
//!   `Caused by:` section.
//!
//! Dropping the real crate back in is a one-line change in
//! rust/Cargo.toml; no call site depends on anything beyond this
//! surface.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error chain: the outermost context message first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap the error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (what `{}` prints).
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the chain from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain on one line, anyhow-style
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// `?` conversion from any std error, capturing its source chain. This
// does not overlap with the reflexive `From<Error> for Error` because
// `Error` itself deliberately does not implement `std::error::Error`
// (same trick the real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message to the error (eagerly evaluated).
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message to the error.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
    }

    #[test]
    fn context_prepends_alternate_shows_chain() {
        let e: Result<()> = std::result::Result::<(), _>::Err(io_err())
            .with_context(|| format!("reading {:?}", "x.json"));
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "reading \"x.json\"");
        assert_eq!(format!("{e:#}"), "reading \"x.json\": missing thing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }

    #[test]
    fn macros() {
        let key = "k";
        let e = anyhow!("missing key `{key}`");
        assert_eq!(format!("{e}"), "missing key `k`");
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).is_err());
        assert!(format!("{}", f(12).unwrap_err()).contains("12"));
    }
}
