//! Type-compatible stub of the `xla` PJRT binding.
//!
//! The real crate (an FFI wrapper over the XLA PJRT C API /
//! `xla_extension`) cannot be vendored: upstream distributes it without
//! a `Cargo.toml` and it drags in a multi-GB native toolchain. This
//! stub declares the exact API subset `hapq`'s `pjrt` feature consumes
//! so that `cargo build/test/doc --features pjrt` works everywhere:
//!
//! * [`Literal`] is fully functional (host-side f32 buffers) — the
//!   literal-marshalling layer and its unit tests run for real;
//! * [`PjRtClient::cpu`] returns an error explaining that no PJRT
//!   runtime is linked, so anything that would actually execute HLO
//!   fails fast with an actionable message instead of at link time.
//!
//! To run the PJRT path for real, point the `xla` path dependency in
//! `rust/Cargo.toml` at a checkout of the real binding (its API is a
//! superset of this file). Every signature here mirrors the real crate.

use std::fmt;

/// Error type mirroring the real binding's `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` alias matching the real binding.
pub type Result<T> = std::result::Result<T, Error>;

fn no_runtime<T>() -> Result<T> {
    Err(Error(
        "this build links the in-tree xla stub, which cannot execute HLO; \
         point rust/Cargo.toml's `xla` path dependency at a real PJRT \
         binding (see rust/vendor/README.md) or use --backend native"
            .to_string(),
    ))
}

/// Element dtype of a [`Literal`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit IEEE float — the only dtype the artifact contract uses.
    F32,
}

/// Trait for element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    /// Decode one element from little-endian bytes.
    fn from_le(bytes: &[u8]) -> Self;
    /// Size of one element in bytes.
    const SIZE: usize;
}

impl NativeType for f32 {
    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
    const SIZE: usize = 4;
}

/// A host-side tensor value (shape + raw little-endian bytes).
///
/// Fully functional in the stub: construction, cloning, readback and
/// the 1-tuple unwrap all behave like the real binding.
#[derive(Clone, Debug)]
pub struct Literal {
    shape: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    /// Build a literal from a dtype, shape and raw bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let ElementType::F32 = ty;
        let n: usize = shape.iter().product();
        if n * 4 != data.len() {
            return Err(Error(format!(
                "shape {shape:?} needs {} bytes, got {}",
                n * 4,
                data.len()
            )));
        }
        Ok(Literal { shape: shape.to_vec(), bytes: data.to_vec() })
    }

    /// Total number of elements.
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    /// Read the buffer back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.bytes.chunks_exact(T::SIZE).map(T::from_le).collect())
    }

    /// Unwrap a 1-tuple result (the exporter emits `return_tuple=True`).
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO *text* file. Stub: always errors (no XLA parser).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        no_runtime()
    }
}

/// An XLA computation handle (opaque in the stub).
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A device buffer holding one execution output.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy the buffer back to a host [`Literal`]. Stub: always errors.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        no_runtime()
    }
}

/// A compiled executable. Stub: can never be constructed successfully.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute on the device; outer vec is per-device, inner per-output.
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        no_runtime()
    }
}

/// The PJRT client. Stub: [`PjRtClient::cpu`] explains how to link a
/// real runtime.
pub struct PjRtClient(());

impl PjRtClient {
    /// Connect to the CPU PJRT plugin. Stub: always errors.
    pub fn cpu() -> Result<PjRtClient> {
        no_runtime()
    }

    /// Platform name of the connected device.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation for this client. Stub: always errors.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        no_runtime()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data: Vec<u8> = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        let l =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &data).unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[7], &data).is_err());
    }

    #[test]
    fn client_errors_actionably() {
        let err = PjRtClient::cpu().err().unwrap().to_string();
        assert!(err.contains("--backend native"), "{err}");
    }
}
